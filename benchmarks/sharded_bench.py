"""Sharded multi-device dispatch: batch-axis shard_map vs single-device vmap.

Two experiments:

(1) device scaling — for each hot signature, one B-wide micro-batch is
    dispatched through ``PlanCache.get_or_compile_sharded`` on data meshes of
    1, 2, 4, ... devices (whatever the host exposes; CI forces 8 fake CPU
    devices via ``XLA_FLAGS=--xla_force_host_platform_device_count=8``) and
    timed against the single-device vmapped executable of the same batch.
    The batch axis is embarrassingly parallel, so on real multi-device
    hardware eligible batches scale with the device count until per-shard
    work is too small to cover the dispatch + transfer overhead. On *forced*
    CPU devices the sharded path typically loses outright: the fake devices
    share one socket (the single-device vmapped program already uses every
    core) while the shard_map adds cross-"device" transfers — so expect
    speedups < 1x here. The per-device-count trend is still exactly what
    this reports, and CI smokes the path on it. Device counts the batch
    doesn't divide fall back (by policy) and are reported as such.

(2) served traffic — the same one-signature request stream pushed through a
    ``QueryServer`` with and without a mesh: end-to-end throughput plus the
    executor's sharded/batched dispatch split, proving the serving tier
    actually picks the sharded executable for eligible batches.

(3) oversized single query — ONE query (no batch axis) whose working set
    busts a per-device memory budget, served through the *partitioned*
    executable (``PlanCache.get_or_compile_partitioned``: PCrossJoin split
    by left rows, pipelines/ML by row block, explicit PRepartition
    collectives) on data meshes of 2, 4, ... devices against the plain
    single-device program. Reports per-device-count dispatch scaling plus
    the analytic per-device peak-memory reduction — the axis that decides
    budget admission. Same fake-CPU caveat as (1): expect wall-clock
    speedups < 1x here; the memory column is the point.
"""
from __future__ import annotations

import time
from typing import List, Sequence

import jax

from benchmarks.common import best_time, csv_line
from repro.core import cost, stage_graph
from repro.core import mesh as mesh_util
from repro.core.plan_cache import PlanCache
from repro.data import workloads
from repro.serving import QueryServer

SCALING_QUERIES = ["simple_q2", "simple_q3"]
OVERSIZED_QUERY = "retail_q3"  # cross-join product dominates the working set


def run(scale: float = 0.08, batch_size: int = 16,
        device_counts: Sequence[int] = (1, 2, 4, 8),
        serve_requests: int = 32, repeats: int = 9):
    lines = []
    n_dev = len(jax.devices())
    counts = [d for d in device_counts if d <= n_dev]
    lines.append(csv_line("sharded/devices", 0.0,
                          f"visible={n_dev} measured={counts}"))

    # -- (1) per-device-count dispatch scaling -----------------------------
    for name in SCALING_QUERIES:
        w = workloads.ALL_WORKLOADS[name](scale=scale)
        cache = PlanCache()
        tabs = tuple(workloads.rolled_instances(dict(w.catalog.tables),
                                                batch_size))
        run_bat = cache.get_or_compile_batched(w.plan, w.catalog, batch_size)
        bat_s = best_time(lambda: run_bat(tabs), repeats)
        lines.append(csv_line(
            f"sharded/{name}/b{batch_size}/d1/vmapped",
            bat_s / batch_size * 1e6, f"qps={batch_size / bat_s:.0f}"))
        for d in counts:
            if d == 1:
                continue
            mesh = mesh_util.data_mesh(d)
            if not mesh_util.can_shard(mesh, batch_size):
                lines.append(csv_line(
                    f"sharded/{name}/b{batch_size}/d{d}/fallback", 0.0,
                    f"batch {batch_size} not divisible by {d} -> vmapped"))
                continue
            run_sh = cache.get_or_compile_sharded(w.plan, w.catalog,
                                                  batch_size, mesh)
            sh_s = best_time(lambda: run_sh(tabs), repeats)
            lines.append(csv_line(
                f"sharded/{name}/b{batch_size}/d{d}/sharded",
                sh_s / batch_size * 1e6,
                f"qps={batch_size / sh_s:.0f} "
                f"speedup={bat_s / sh_s:.2f}x"))

    # -- (2) the serving tier picks the sharded executable -----------------
    w = workloads.ALL_WORKLOADS[SCALING_QUERIES[0]](scale=scale)
    base = dict(w.catalog.tables)
    payloads = [workloads.roll_tables(base, i) for i in range(serve_requests)]
    mesh = mesh_util.data_mesh(counts[-1]) if counts[-1] > 1 else None
    shared_cache = PlanCache()

    def serve_all(server: QueryServer) -> float:
        t0 = time.perf_counter()
        for tabs in payloads:
            server.submit(w.plan, w.catalog, tabs)
            server.step()  # size-triggered dispatch of any full group
        server.drain()
        return time.perf_counter() - t0

    def measure(mk_server, n: int = 3):
        serve_all(mk_server())  # warmup compiles every batch size formed
        times, srv = [], None
        for _ in range(n):
            srv = mk_server()
            times.append(serve_all(srv))
        return min(times), srv

    bat_s, _ = measure(lambda: QueryServer(
        cache=shared_cache, max_batch_size=8, max_wait_s=3600.0))
    sh_s, sh_srv = measure(lambda: QueryServer(
        cache=shared_cache, max_batch_size=8, max_wait_s=3600.0, mesh=mesh))
    st = sh_srv.stats()
    lines.append(csv_line(
        "sharded/serve/vmapped", bat_s / serve_requests * 1e6,
        f"qps={serve_requests / bat_s:.0f}"))
    lines.append(csv_line(
        "sharded/serve/sharded", sh_s / serve_requests * 1e6,
        f"qps={serve_requests / sh_s:.0f} speedup={bat_s / sh_s:.2f}x "
        f"sharded_dispatches={st['sharded_dispatches']} "
        f"dispatches={st['dispatches']}"))

    # -- (3) oversized single query: partitioned operators -----------------
    w = workloads.ALL_WORKLOADS[OVERSIZED_QUERY](scale=scale)
    profile = cost.DeviceProfile.detect()
    plain_cache = PlanCache()
    tabs = dict(w.catalog.tables)
    run_plain = plain_cache.get_or_compile(w.plan, w.catalog)
    plain_s = best_time(lambda: run_plain(tabs), repeats)
    g1 = stage_graph.build(w.plan, w.catalog, profile=profile)
    peak_rep = cost.phys_peak_memory(g1.realize(g1.default_decisions()),
                                     w.catalog, profile)
    lines.append(csv_line(
        f"sharded/oversized/{OVERSIZED_QUERY}/d1/plain", plain_s * 1e6,
        f"peak_mb={peak_rep / 1e6:.2f}"))
    for d in counts:
        if d == 1:
            continue
        mesh = mesh_util.data_mesh(d)
        # a budget below the unpartitioned working set forces the costed
        # lowering onto a partitioned plan that fits (its own cache: the
        # budget must not leak into the plain baseline's decisions)
        g = stage_graph.build(w.plan, w.catalog, profile=profile, ways=d)
        peak_part = cost.phys_peak_memory(
            g.realize(g.partitioned_decisions()), w.catalog, profile)
        part_cache = PlanCache()
        part_cache.profile.memory_budget = (peak_part + peak_rep) / 2.0
        run_part = part_cache.get_or_compile_partitioned(
            w.plan, w.catalog, mesh)
        part_s = best_time(lambda: run_part(tabs), repeats)
        lines.append(csv_line(
            f"sharded/oversized/{OVERSIZED_QUERY}/d{d}/partitioned",
            part_s * 1e6,
            f"speedup={plain_s / part_s:.2f}x "
            f"peak_mb={peak_part / 1e6:.2f} "
            f"peak_shrink={peak_rep / max(peak_part, 1.0):.2f}x"))
    return lines


if __name__ == "__main__":
    for ln in run():
        print(ln)
