"""Sec. V-C5 — the randomly-generated inference-query benchmark: a fleet
sampled from the 20 templates (ID/OOD split), reporting per-query optimized
cost improvements across the fleet."""
from __future__ import annotations

import numpy as np

from repro.core.planner import STRATEGIES, analytic_cost_fn
from repro.data import templates
from benchmarks.common import csv_line


def run(n_queries: int = 40, iterations: int = 12, seed: int = 3):
    ind, ood = templates.ood_split()
    rng = np.random.default_rng(seed)
    speedups, lines = [], []
    for i in range(n_queries):
        pool = ind if i % 3 else ood
        t = pool[int(rng.integers(0, len(pool)))]
        plan, cat = templates.sample_query(t, seed=40_000 + i, scale=0.5)
        cost_fn = analytic_cost_fn(cat)
        c0 = cost_fn(plan)
        p2, _ = STRATEGIES["vanilla_mcts"](plan, cat, cost_fn=cost_fn,
                                           iterations=iterations, seed=i)
        speedups.append(c0 / max(cost_fn(p2), 1e-12))
    sp = np.array(speedups)
    lines.append(csv_line(
        "randomfleet/summary", 0.0,
        f"n={n_queries} mean_speedup={sp.mean():.2f}x "
        f"p50={np.median(sp):.2f}x p90={np.percentile(sp, 90):.2f}x "
        f"max={sp.max():.2f}x improved={int((sp > 1.01).sum())}/{n_queries}"))
    return lines


if __name__ == "__main__":
    for ln in run():
        print(ln)
