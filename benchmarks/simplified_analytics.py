"""Fig. 7 / Fig. 8 — retailing-simplified + analytics workloads at two
scales, un-optimized vs optimized (incl. the R4-2 Pallas-backend plans)."""
from __future__ import annotations

from repro.core.planner import STRATEGIES, analytic_cost_fn
from repro.data import workloads
from benchmarks.common import csv_line, time_plan

QUERIES = ["simple_q1", "simple_q2", "simple_q3",
           "analytics_q1", "analytics_q2", "analytics_q3"]


def run(scales=(1.0, 4.0), iterations: int = 25):
    lines = []
    for scale in scales:
        for name in QUERIES:
            w = workloads.ALL_WORKLOADS[name](scale=scale)
            cost_fn = analytic_cost_fn(w.catalog, memory_budget=w.memory_budget)
            base_t, _ = time_plan(w.plan, w.catalog)
            opt_plan, _ = STRATEGIES["vanilla_mcts"](
                w.plan, w.catalog, cost_fn=cost_fn, iterations=iterations,
                seed=0)
            opt_t, _ = time_plan(opt_plan, w.catalog)
            lines.append(csv_line(
                f"fig78/{name}@{scale:g}/unoptimized", base_t * 1e6, ""))
            lines.append(csv_line(
                f"fig78/{name}@{scale:g}/cactusdb", opt_t * 1e6,
                f"speedup={base_t / max(opt_t, 1e-9):.2f}x"))
    return lines


if __name__ == "__main__":
    for ln in run():
        print(ln)
