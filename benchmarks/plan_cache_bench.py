"""Compiled-plan cache: repeated / parameterized query traffic.

Runs each workload twice through one ``PlanCache`` — the query a serving
tier would see from two users asking the same (structurally identical)
question — and reports cold vs warm dispatch latency, cache hits/misses,
and the jax trace count (a warm hit must add zero re-traces).
"""
from __future__ import annotations

import time

import jax

from repro.core.plan_cache import PlanCache
from repro.data import workloads
from benchmarks.common import csv_line

QUERIES = ["rec_q1", "retail_q1", "simple_q2", "analytics_q1"]


def run(scale: float = 0.5):
    lines = []
    cache = PlanCache()
    for name in QUERIES:
        w = workloads.ALL_WORKLOADS[name](scale=scale)
        tables = dict(w.catalog.tables)

        t0 = time.perf_counter()
        fn = cache.get_or_compile(w.plan, w.catalog)
        jax.block_until_ready(fn(tables))
        cold_s = time.perf_counter() - t0
        traces_after_cold = cache.traces

        # second, structurally identical query (fresh Workload build → fresh
        # logical tree and registry, same signature)
        w2 = workloads.ALL_WORKLOADS[name](scale=scale)
        t0 = time.perf_counter()
        fn2 = cache.get_or_compile(w2.plan, w2.catalog)
        jax.block_until_ready(fn2(dict(w2.catalog.tables)))
        warm_s = time.perf_counter() - t0
        retraces = cache.traces - traces_after_cold

        lines.append(csv_line(f"plan_cache/{name}/cold", cold_s * 1e6))
        lines.append(csv_line(
            f"plan_cache/{name}/warm", warm_s * 1e6,
            f"speedup={cold_s / max(warm_s, 1e-9):.1f}x retraces={retraces}"))
    s = cache.stats
    lines.append(csv_line(
        "plan_cache/totals", 0.0,
        f"hits={s.hits} misses={s.misses} hit_rate={s.hit_rate:.2f} "
        f"traces={cache.traces}"))
    return lines


if __name__ == "__main__":
    for ln in run():
        print(ln)
