"""Benchmark driver — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. ``--quick`` shrinks workload scales
and MCTS budgets for CI-speed runs; the default configuration is what
bench_output.txt records.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark names")
    args = ap.parse_args()
    q = args.quick

    from benchmarks import (ablation, complex_queries, kernels_bench,
                            optimizers, plan_cache_bench, random_queries,
                            roofline, simplified_analytics)

    suites = {
        "kernels": lambda: kernels_bench.run(),
        "plan_cache": lambda: plan_cache_bench.run(scale=0.3 if q else 0.5),
        "complex_queries": lambda: complex_queries.run(
            scale=0.5 if q else 1.0, iterations=15 if q else 40),
        "ablation": lambda: ablation.run(
            scale=0.5 if q else 1.0, iterations=10 if q else 25),
        "simplified_analytics": lambda: simplified_analytics.run(
            scales=(0.5,) if q else (1.0, 3.0), iterations=8 if q else 18),
        "optimizers": lambda: optimizers.run(
            n_id=8 if q else 24, n_ood=4 if q else 12,
            iterations=6 if q else 15, train_steps=30 if q else 80),
        "random_queries": lambda: random_queries.run(
            n_queries=8 if q else 24, iterations=5 if q else 10),
        "roofline": lambda: roofline.run(),
    }
    only = set(args.only.split(",")) if args.only else None
    print("name,us_per_call,derived")
    for name, fn in suites.items():
        if only and name not in only:
            continue
        t0 = time.time()
        try:
            for line in fn():
                print(line, flush=True)
            print(f"# suite {name} done in {time.time() - t0:.1f}s",
                  file=sys.stderr)
        except Exception:
            print(f"# suite {name} FAILED", file=sys.stderr)
            traceback.print_exc()


if __name__ == "__main__":
    main()
