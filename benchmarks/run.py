"""Benchmark driver — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. ``--quick`` shrinks workload scales
and MCTS budgets for CI-speed runs; the default configuration is what
bench_output.txt records. ``--json PATH`` additionally writes a machine-
readable summary (rows + per-suite wall time + failures) — CI uploads it as
an artifact. A suite that raises marks the run failed (nonzero exit), so
dispatch-path regressions in smoke-benchmarked suites fail CI.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark names")
    ap.add_argument("--json", default=None,
                    help="write a JSON summary of all rows to this path")
    args = ap.parse_args()
    q = args.quick

    from benchmarks import (ablation, complex_queries, cost_model_bench,
                            kernels_bench, optimizers, plan_cache_bench,
                            random_queries, roofline, serving_bench,
                            sharded_bench, simplified_analytics)

    suites = {
        "kernels": lambda: kernels_bench.run(),
        "plan_cache": lambda: plan_cache_bench.run(scale=0.3 if q else 0.5),
        # cost-oracle accuracy: predicted vs measured + calibration error;
        # the JSON summary gains a `cost_model` section from this suite
        "cost": lambda: cost_model_bench.run(
            scale=0.3 if q else 0.5, repeats=5 if q else 9,
            queries=cost_model_bench.QUICK_QUERIES if q else None),
        "serving": lambda: serving_bench.run(
            scale=0.08, batch_sizes=(1, 2, 8, 16) if q else (1, 2, 4, 8, 16),
            mix_requests=21 if q else 42, repeats=7 if q else 15),
        # multi-device batch sharding; CI forces 8 fake CPU devices via
        # XLA_FLAGS=--xla_force_host_platform_device_count=8 for this suite
        "sharded": lambda: sharded_bench.run(
            scale=0.08, batch_size=8 if q else 16,
            serve_requests=16 if q else 32, repeats=5 if q else 9),
        "complex_queries": lambda: complex_queries.run(
            scale=0.5 if q else 1.0, iterations=15 if q else 40),
        "ablation": lambda: ablation.run(
            scale=0.5 if q else 1.0, iterations=10 if q else 25),
        "simplified_analytics": lambda: simplified_analytics.run(
            scales=(0.5,) if q else (1.0, 3.0), iterations=8 if q else 18),
        "optimizers": lambda: optimizers.run(
            n_id=8 if q else 24, n_ood=4 if q else 12,
            iterations=6 if q else 15, train_steps=30 if q else 80),
        "random_queries": lambda: random_queries.run(
            n_queries=8 if q else 24, iterations=5 if q else 10),
        "roofline": lambda: roofline.run(),
    }
    only = set(args.only.split(",")) if args.only else None
    if only:
        unknown = only - set(suites)
        if unknown:
            # a typo'd --only must not silently benchmark nothing (CI
            # relies on this run as a regression gate)
            print(f"unknown suite(s): {sorted(unknown)}; "
                  f"available: {sorted(suites)}", file=sys.stderr)
            sys.exit(2)
    summary = {"quick": q, "suites": {}, "rows": [], "failed": []}

    def write_summary():
        # rewritten after every suite so a timeout kill still leaves the
        # partial artifact for diagnosis
        if args.json:
            with open(args.json, "w") as f:
                json.dump(summary, f, indent=2)

    print("name,us_per_call,derived")
    for name, fn in suites.items():
        if only and name not in only:
            continue
        t0 = time.time()
        try:
            for line in fn():
                print(line, flush=True)
                parts = line.split(",", 2)
                summary["rows"].append({
                    "name": parts[0],
                    "us_per_call": float(parts[1]) if len(parts) > 1 else None,
                    "derived": parts[2] if len(parts) > 2 else ""})
            summary["suites"][name] = round(time.time() - t0, 1)
            if name == "cost":
                # oracle-accuracy tracking across PRs (BENCH_*.json)
                summary["cost_model"] = cost_model_bench.LAST_SUMMARY
            print(f"# suite {name} done in {time.time() - t0:.1f}s",
                  file=sys.stderr)
        except Exception:
            summary["failed"].append(name)
            print(f"# suite {name} FAILED", file=sys.stderr)
            traceback.print_exc()
        write_summary()
    if summary["failed"]:
        sys.exit(1)


if __name__ == "__main__":
    main()
