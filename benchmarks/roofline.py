"""EXPERIMENTS §Roofline — renders the per-(arch x shape x mesh) roofline
table from the dry-run artifacts in experiments/dryrun/*.json."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import csv_line


def load_records(dryrun_dir: str = "experiments/final"):
    recs = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def run(dryrun_dir: str = "experiments/final"):
    lines = []
    for r in load_records(dryrun_dir):
        tag = f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}"
        if r.get("status") == "skipped":
            lines.append(csv_line(tag, 0.0, "skipped"))
            continue
        if r.get("status") != "ok":
            lines.append(csv_line(tag, 0.0, f"error={r.get('error', '?')[:80]}"))
            continue
        rt = r["roofline"]
        dom = r["bottleneck"]
        step_us = max(rt.values()) * 1e6
        lines.append(csv_line(
            tag, step_us,
            f"compute_s={rt['compute_s']:.4g} memory_s={rt['memory_s']:.4g} "
            f"collective_s={rt['collective_s']:.4g} bottleneck={dom} "
            f"useful_ratio={r.get('useful_ratio')} "
            f"frac={r.get('roofline_fraction')} "
            f"mem_gb={r.get('memory', {}).get('per_device_total_gb')}"))
    return lines


def markdown_table(dryrun_dir: str = "experiments/final",
                   mesh: str = "16x16") -> str:
    """The §Roofline table for EXPERIMENTS.md."""
    rows = ["| arch | shape | compute_s | memory_s | collective_s | "
            "bottleneck | MODEL_FLOPS | useful ratio | roofline frac | mem/dev GB |",
            "|---|---|---|---|---|---|---|---|---|---|"]
    for r in load_records(dryrun_dir):
        if r.get("mesh") != mesh:
            continue
        if r.get("status") == "skipped":
            rows.append(f"| {r['arch']} | {r['shape']} | — | — | — | skipped | "
                        f"— | — | — | — |")
            continue
        if r.get("status") != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | — | — | — | ERROR | "
                        f"— | — | — | — |")
            continue
        rt = r["roofline"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {rt['compute_s']:.3g} | "
            f"{rt['memory_s']:.3g} | {rt['collective_s']:.3g} | "
            f"{r['bottleneck'].replace('_s', '')} | {r['model_flops']:.3g} | "
            f"{r.get('useful_ratio')} | {r.get('roofline_fraction')} | "
            f"{r.get('memory', {}).get('per_device_total_gb', '—')} |")
    return "\n".join(rows)


if __name__ == "__main__":
    for ln in run():
        print(ln)
