"""Table IV + Fig. 9/10 — optimizer strategies compared on a random-query
fleet: un-optimized / arbitrary / heuristic / vanilla MCTS / reusable MCTS
(two-model and one-model variants), with opt-vs-exec split, ID/OOD collision
rates, and node-store storage overhead."""
from __future__ import annotations

import time

import numpy as np

from repro.core import optimizer as om
from repro.core.mcts import ReusableMCTS
from repro.core.planner import STRATEGIES, analytic_cost_fn, timed
from repro.data import templates
from benchmarks.common import csv_line


def _train_embedder(n_train: int = 60, steps: int = 120, seed: int = 0,
                    one_model: bool = False):
    emb = om.init_embedder(seed)
    ind, _ = templates.ood_split()
    from repro.mlfuncs import builders
    graphs = [g for g in (builders.sample_model(s).graph for s in range(40))
              if g is not None]
    om.train_model2vec(emb, graphs, steps=steps, batch=8, lr=1e-4)
    plans, cats, costs = [], [], []
    rng = np.random.default_rng(seed)
    for i in range(n_train):
        t = ind[int(rng.integers(0, len(ind)))]
        p, c = templates.sample_query(t, seed=10_000 + i, scale=0.5)
        plans.append(p)
        cats.append(c)
        costs.append(analytic_cost_fn(c)(p))
    om.train_query2vec(emb, plans, cats, steps=steps, batch=8)
    om.train_latency(emb, plans, cats, costs, steps=2 * steps, batch=12,
                     one_model=one_model)
    pred = np.array([emb.predict_latency(p, c) for p, c in zip(plans, cats)])
    qe = om.q_error(pred, np.array(costs))
    corr = float(np.corrcoef(np.log(pred + 1e-12), np.log(costs))[0, 1])
    return emb, float(np.median(qe)), corr


def run(n_id: int = 40, n_ood: int = 20, iterations: int = 20,
        train_steps: int = 120):
    lines = []
    emb, med_q, corr = _train_embedder(steps=train_steps)
    lines.append(csv_line("optbench/latency_model/two_model", 0.0,
                          f"median_q_error={med_q:.2f} corr={corr:.3f}"))
    emb1, med_q1, corr1 = _train_embedder(steps=train_steps, one_model=True,
                                          seed=1)
    lines.append(csv_line("optbench/latency_model/one_model", 0.0,
                          f"median_q_error={med_q1:.2f} corr={corr1:.3f}"))

    ind, ood = templates.ood_split()
    rng = np.random.default_rng(7)
    fleet = []
    for i in range(n_id):
        t = ind[int(rng.integers(0, len(ind)))]
        fleet.append(("ID",) + templates.sample_query(t, seed=20_000 + i,
                                                      scale=0.5))
    for i in range(n_ood):
        t = ood[int(rng.integers(0, len(ood)))]
        fleet.append(("OOD",) + templates.sample_query(t, seed=30_000 + i,
                                                       scale=0.5))

    # classic strategies
    for strat in ["unoptimized", "arbitrary", "heuristic", "vanilla_mcts"]:
        opt_total, exec_total = 0.0, 0.0
        for split, plan, cat in fleet:
            cost_fn = analytic_cost_fn(cat)
            p2, stats = timed(STRATEGIES[strat], plan, cat, cost_fn=cost_fn,
                              iterations=iterations)
            opt_total += stats["opt_seconds"]
            exec_total += cost_fn(p2)
        lines.append(csv_line(
            f"tableIV/{strat}", opt_total / len(fleet) * 1e6,
            f"opt_s={opt_total:.1f} exec_s={exec_total:.4f} "
            f"total_s={opt_total + exec_total:.1f}"))

    # reusable MCTS (two-model)
    for label, embedder in [("reusable_two_model", emb),
                            ("reusable_one_model", emb1)]:
        r = ReusableMCTS(catalog_fn=None, embed_fn=embedder.embed,
                         cost_fn_factory=lambda c: analytic_cost_fn(c),
                         iterations=iterations,
                         warm_iterations=max(iterations // 4, 4),
                         sim_threshold=0.98, seed=0)
        stats_by_split = {"ID": [0.0, 0.0, 0, 0], "OOD": [0.0, 0.0, 0, 0]}
        for split, plan, cat in fleet:
            t0 = time.perf_counter()
            p2, stats = r.optimize(plan, cat)
            dt = time.perf_counter() - t0
            s = stats_by_split[split]
            s[0] += dt
            s[1] += analytic_cost_fn(cat)(p2)
            s[2] += int(stats["collision"])
            s[3] += 1
        for split, (opt_s, exec_s, coll, n) in stats_by_split.items():
            lines.append(csv_line(
                f"tableIV/{label}/{split}", opt_s / max(n, 1) * 1e6,
                f"opt_s={opt_s:.1f} exec_s={exec_s:.4f} "
                f"collision_rate={coll / max(n, 1):.2f}"))
        lines.append(csv_line(
            f"tableIV/{label}/storage", 0.0,
            f"nodes={len(r.nodes)} bytes={r.storage_bytes()}"))
    return lines


if __name__ == "__main__":
    for ln in run():
        print(ln)
