"""Cost-oracle accuracy: predicted vs measured seconds per workload.

For each workload the analytic oracle's prediction (``cost.plan_cost`` of
the costed-lowered physical plan, detected profile) is compared against the
measured warm dispatch wall-clock of the compiled executable. The summary
(exported into ``benchmarks.run --json`` as the ``cost_model`` section)
tracks the mean absolute percentage error (MAPE) across PRs, plus the MAPE
after one round of ``fit_profile`` calibration on the same measurements —
the gap between the two is what the serving feedback loop can recover
online. Costed-vs-tree-order lowering gains are reported per workload (the
oracle's *decisions*, not just its absolute accuracy).
"""
from __future__ import annotations

from typing import Dict, Iterable, List

import jax

from repro.core import cost
from repro.core.lowering import lower
from repro.core import physical as ph
from repro.core.plan_cache import PlanCache
from repro.data import workloads
from benchmarks.common import best_time, csv_line

QUICK_QUERIES = ["rec_q1", "rec_q2", "retail_q1", "simple_q2"]

# populated by run(); benchmarks.run lifts it into the JSON summary
LAST_SUMMARY: Dict[str, object] = {}


def run(scale: float = 0.5, repeats: int = 7,
        queries: Iterable[str] | None = None) -> List[str]:
    global LAST_SUMMARY
    lines: List[str] = []
    profile = cost.DeviceProfile.detect()
    cache = PlanCache(profile=profile)
    per_workload: Dict[str, Dict[str, float]] = {}
    samples = []
    for name in (sorted(workloads.ALL_WORKLOADS) if queries is None
                 else list(queries)):
        w = workloads.ALL_WORKLOADS[name](scale=scale)
        pplan = lower(w.plan, w.catalog, profile=profile)
        predicted = cost.plan_cost(pplan, w.catalog, profile)
        tree_cost = cost.plan_cost(lower(w.plan, w.catalog, costed=False),
                                   w.catalog, profile)
        tables = dict(w.catalog.tables)
        fn = cache.get_or_compile(w.plan, w.catalog)
        measured = best_time(lambda: fn(tables), repeats=repeats)
        err = abs(predicted - measured) / max(measured, 1e-12)
        per_workload[name] = {
            "predicted_s": predicted, "measured_s": measured,
            "tree_order_s": tree_cost,
            "costed_gain": tree_cost / max(predicted, 1e-12),
            "ape": err,
        }
        # breakdown of the *costed* physical plan: the features must
        # describe the executable that was actually timed
        samples.append((cost.plan_cost_breakdown(pplan, w.catalog, profile),
                        measured, 1.0))
        lines.append(csv_line(
            f"cost/{name}", measured * 1e6,
            f"predicted_us={predicted * 1e6:.1f} "
            f"ratio={predicted / max(measured, 1e-12):.2f} "
            f"costed_gain={tree_cost / max(predicted, 1e-12):.3f}x"))
    fit = cost.fit_profile(samples, profile)
    mape = (sum(v["ape"] for v in per_workload.values())
            / max(len(per_workload), 1))
    lines.append(csv_line(
        "cost/calibration", 0.0,
        f"mape={mape:.3f} mape_calibrated={fit.mape_after:.3f} "
        f"n={fit.n_samples} profile={profile.name}"))
    LAST_SUMMARY = {
        "profile": profile.name,
        "scale": scale,
        "per_workload": per_workload,
        "mape": mape,
        "mape_linearized": fit.mape_before,
        "mape_calibrated": fit.mape_after,
        "calibrated_profile": {
            "peak_flops": fit.profile.peak_flops,
            "hbm_bw": fit.profile.hbm_bw,
            "op_overhead_s": fit.profile.op_overhead_s,
        },
    }
    return lines


if __name__ == "__main__":
    for ln in run():
        print(ln)
