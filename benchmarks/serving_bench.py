"""Serving tier: batched vs sequential dispatch over the compiled-plan cache.

Two experiments:

(1) dispatch scaling — for one hot signature, time B sequential warm cached
    dispatches (each blocking, exactly like the singleton server path)
    against one B-wide vmapped dispatch of the same plan, across batch
    sizes. Repeated parameterized queries are small per request, so the
    serving win is amortizing per-dispatch overhead (python + jit call +
    launch) across the batch: the dispatch-bound hot queries here show the
    >= 2x batched throughput at B >= 8 that motivates the tier. Compute-
    bound analytics queries saturate a CPU either way (and only win on
    accelerators where the batch axis fills idle lanes), so they belong in
    the traffic mix, not the scaling sweep.

(2) traffic mix — M in-flight requests spread over several signatures at a
    given mix ratio, pushed through the ``QueryServer``; reports end-to-end
    throughput vs a batch-size-1 server and the scheduler's grouping stats
    (micro-batches formed, mean batch occupancy, per-signature occupancy).
    Group formation is size-triggered (full groups dispatch during
    submission, remainders at drain), so grouping is deterministic and the
    warmup run pre-compiles every batch size the measured run sees.
"""
from __future__ import annotations

import time
from typing import List, Sequence, Tuple

import jax

from benchmarks.common import best_time as _best_time, csv_line
from repro.core.plan_cache import PlanCache
from repro.data import workloads
from repro.serving import QueryServer

SCALING_QUERIES = ["simple_q2", "simple_q3"]
MIX_QUERIES = ["simple_q1", "simple_q2", "simple_q3"]


def run(scale: float = 0.08, batch_sizes: Sequence[int] = (1, 2, 4, 8, 16),
        mix_requests: int = 42, mix_ratio: Sequence[int] = (4, 2, 1),
        max_batch_size: int = 8, repeats: int = 15):
    lines = []

    # -- (1) dispatch scaling ---------------------------------------------
    for name in SCALING_QUERIES:
        w = workloads.ALL_WORKLOADS[name](scale=scale)
        cache = PlanCache()
        base = dict(w.catalog.tables)
        run_seq = cache.get_or_compile(w.plan, w.catalog)
        for b in batch_sizes:
            tabs = tuple(workloads.rolled_instances(base, b))
            seq_s = _best_time(
                lambda: [jax.block_until_ready(run_seq(t)) for t in tabs],
                repeats)
            run_bat = cache.get_or_compile_batched(w.plan, w.catalog, b)
            bat_s = _best_time(lambda: run_bat(tabs), repeats)
            lines.append(csv_line(
                f"serving/{name}/b{b}/sequential", seq_s / b * 1e6,
                f"qps={b / seq_s:.0f}"))
            lines.append(csv_line(
                f"serving/{name}/b{b}/batched", bat_s / b * 1e6,
                f"qps={b / bat_s:.0f} speedup={seq_s / bat_s:.2f}x"))

    # -- (2) traffic mix through the server -------------------------------
    built = {n: workloads.ALL_WORKLOADS[n](scale=scale) for n in MIX_QUERIES}
    order: List[str] = []
    while len(order) < mix_requests:
        for name, k in zip(MIX_QUERIES, mix_ratio):
            order.extend([name] * k)
    order = order[:mix_requests]
    # request payloads prepared up front: the measured window is pure serving
    payloads: List[Tuple] = []
    for i, name in enumerate(order):
        w = built[name]
        payloads.append((w.plan, w.catalog,
                         workloads.roll_tables(dict(w.catalog.tables), i)))

    def serve_all(server: QueryServer) -> float:
        t0 = time.perf_counter()
        for plan, catalog, tabs in payloads:
            server.submit(plan, catalog, tabs)
            server.step()  # size-triggered dispatch of any full group
        server.drain()
        return time.perf_counter() - t0

    shared_cache = PlanCache()

    def measure(mk_server, n: int = 3):
        """Warmup once (compiles every (signature, batch size) the run
        forms), then best of n fresh-server runs over the shared cache."""
        serve_all(mk_server())
        times, srv = [], None
        for _ in range(n):
            srv = mk_server()
            times.append(serve_all(srv))
        return min(times), srv

    batched_s, batched_srv = measure(
        lambda: QueryServer(cache=shared_cache,
                            max_batch_size=max_batch_size,
                            max_wait_s=3600.0))
    seq_s, _ = measure(
        lambda: QueryServer(cache=shared_cache, max_batch_size=1,
                            max_wait_s=0.0))

    st = batched_srv.stats()
    lines.append(csv_line(
        "serving/mix/sequential", seq_s / mix_requests * 1e6,
        f"qps={mix_requests / seq_s:.0f}"))
    lines.append(csv_line(
        "serving/mix/batched", batched_s / mix_requests * 1e6,
        f"qps={mix_requests / batched_s:.0f} "
        f"speedup={seq_s / batched_s:.2f}x"))
    lines.append(csv_line(
        "serving/mix/grouping", 0.0,
        f"signatures={st['signatures']} groups={st['groups_formed']} "
        f"mean_occupancy={st['mean_occupancy']:.2f}"))
    for i, sig in enumerate(batched_srv.signatures.values()):
        short = sig.key.split("@", 1)[0][:40]
        lines.append(csv_line(
            f"serving/mix/sig{i}", sig.mean_dispatch_s * 1e6,
            f"requests={sig.requests} dispatches={sig.dispatches} "
            f"occupancy={sig.mean_occupancy:.2f} plan={short}"))
    return lines


if __name__ == "__main__":
    for ln in run():
        print(ln)
