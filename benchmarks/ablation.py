"""Table II — speedup from each co-optimization category alone vs combined.
MCTS runs with the action space restricted to one category at a time."""
from __future__ import annotations

from repro.core.mcts import VanillaMCTS
from repro.core.planner import analytic_cost_fn
from repro.data import workloads
from benchmarks.common import csv_line, time_plan

CATEGORY_ACTIONS = {
    "O1": ["R1-1", "R1-2", "R1-3", "R1-4-merge", "R1-4-split", "compact"],
    # factorized inference intrinsically pushes the factored parts through
    # the join (paper Fig. 1), so O2 includes split+push of the factors
    "O2": ["R2-1", "R2-3", "R4-1-split", "R1-3"],
    "O3": ["R3-1", "R3-2", "R3-3"],
    "O4": ["R4-1-fuse", "R4-1-split", "R4-1-unfuse", "R4-2", "R4-4"],
    "combined": None,  # full action space
}

QUERIES = ["rec_q1", "rec_q2", "retail_q1", "retail_q2"]


def run(scale: float = 1.0, iterations: int = 35):
    lines = []
    for name in QUERIES:
        w = workloads.ALL_WORKLOADS[name](scale=scale)
        cost_fn = analytic_cost_fn(w.catalog, memory_budget=w.memory_budget)
        base_t, _ = time_plan(w.plan, w.catalog)
        lines.append(csv_line(f"tableII/{name}/unoptimized", base_t * 1e6,
                              "speedup=1.0x"))
        for cat_name, actions in CATEGORY_ACTIONS.items():
            m = VanillaMCTS(w.catalog, cost_fn, iterations=iterations,
                            seed=0, actions=actions)
            best, _ = m.optimize(w.plan)
            t, _ = time_plan(best, w.catalog)
            lines.append(csv_line(
                f"tableII/{name}/{cat_name}", t * 1e6,
                f"speedup={base_t / max(t, 1e-9):.2f}x"))
    return lines


if __name__ == "__main__":
    for ln in run():
        print(ln)
