"""Benchmark helpers: wall-clock measurement of compiled plans."""
from __future__ import annotations

import time
from typing import Callable, Tuple

import jax

from repro.core import ir
from repro.core.executor import execute_node


def time_plan(plan: ir.Plan, catalog: ir.Catalog, repeats: int = 3
              ) -> Tuple[float, float]:
    """Returns (median wall seconds, compile seconds)."""
    tables = dict(catalog.tables)

    @jax.jit
    def run():
        return execute_node(plan.root, tables, plan.registry)

    t0 = time.perf_counter()
    out = run()
    jax.block_until_ready(out)
    compile_s = time.perf_counter() - t0
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = run()
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2], compile_s


def time_fn(fn: Callable, *args, repeats: int = 5) -> float:
    out = fn(*args)
    jax.block_until_ready(out)
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def csv_line(name: str, us_per_call: float, derived: str = "") -> str:
    return f"{name},{us_per_call:.1f},{derived}"
