"""Benchmark helpers: wall-clock measurement of compiled plans."""
from __future__ import annotations

import time
from typing import Callable, Optional, Tuple

import jax

from repro.core import ir
from repro.core import physical as ph
from repro.core.lowering import lower
from repro.core.plan_cache import PlanCache


def time_plan(plan: ir.Plan, catalog: ir.Catalog, repeats: int = 3,
              cache: Optional[PlanCache] = None) -> Tuple[float, float]:
    """Returns (median wall seconds, compile seconds).

    Goes through the physical path (lower + jit). With ``cache`` given the
    compiled executable is shared/reused through the plan cache, so the
    compile-seconds of a repeated plan collapse to a cache lookup.
    """
    tables = dict(catalog.tables)
    if cache is not None:
        run_tables = cache.get_or_compile(plan, catalog)
        run = lambda: run_tables(tables)
    else:
        pplan = lower(plan, catalog)
        run = jax.jit(lambda: ph.run(pplan, tables))

    t0 = time.perf_counter()
    out = run()
    jax.block_until_ready(out)
    compile_s = time.perf_counter() - t0
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = run()
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2], compile_s


def best_time(fn: Callable, repeats: int = 9) -> float:
    """Min over repeats: the standard noise-robust microbenchmark estimator
    (load spikes only ever add time). The first call runs outside the
    window, warming/compiling whatever the closure touches."""
    jax.block_until_ready(fn())
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    return min(ts)


def time_fn(fn: Callable, *args, repeats: int = 5) -> float:
    out = fn(*args)
    jax.block_until_ready(out)
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def csv_line(name: str, us_per_call: float, derived: str = "") -> str:
    return f"{name},{us_per_call:.1f},{derived}"
