"""Table I — end-to-end latency of the 6 complex inference queries,
un-optimized vs CACTUSDB (reusable-MCTS plan), measured wall clock on the
compiled engine, plus peak-memory estimates (the paper's OOM axis)."""
from __future__ import annotations

from repro.core.cost import plan_peak_memory
from repro.core.executor import execute
from repro.core.planner import STRATEGIES, analytic_cost_fn, timed
from repro.data import workloads
from benchmarks.common import csv_line, time_plan

QUERIES = ["rec_q1", "rec_q2", "rec_q3", "retail_q1", "retail_q2", "retail_q3"]


def run(scale: float = 1.0, iterations: int = 50, verify: bool = True):
    lines = []
    for name in QUERIES:
        w = workloads.ALL_WORKLOADS[name](scale=scale)
        cost_fn = analytic_cost_fn(w.catalog, memory_budget=w.memory_budget)
        base_t, _ = time_plan(w.plan, w.catalog)
        opt_plan, stats = timed(STRATEGIES["vanilla_mcts"], w.plan, w.catalog,
                                cost_fn=cost_fn, iterations=iterations, seed=0)
        opt_t, _ = time_plan(opt_plan, w.catalog)
        if verify:
            import numpy as np
            a = execute(w.plan, w.catalog).canonical()
            b = execute(opt_plan, w.catalog).canonical()
            for k in a:
                np.testing.assert_allclose(a[k], b[k], rtol=5e-4, atol=5e-4)
        mem0 = plan_peak_memory(w.plan, w.catalog) / 1e6
        mem1 = plan_peak_memory(opt_plan, w.catalog) / 1e6
        speed = base_t / max(opt_t, 1e-9)
        lines.append(csv_line(
            f"tableI/{name}/unoptimized", base_t * 1e6,
            f"mem={mem0:.1f}MB"))
        lines.append(csv_line(
            f"tableI/{name}/cactusdb", opt_t * 1e6,
            f"speedup={speed:.1f}x opt_s={stats['opt_seconds']:.2f} "
            f"mem={mem1:.1f}MB verified=ok"))
    return lines


if __name__ == "__main__":
    for ln in run():
        print(ln)
