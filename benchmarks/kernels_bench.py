"""Per-kernel microbenchmarks (interpret mode on CPU — numbers demonstrate
the harness; TPU wall-clock comes from the same entry points on hardware)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_line, time_fn

rng = np.random.default_rng(0)


def run():
    lines = []
    from repro.kernels.fused_dense import ops as fd
    x = jnp.asarray(rng.standard_normal((256, 512)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((512, 256)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((256,)), jnp.float32)
    t = time_fn(lambda: fd.fused_dense(x, w, b, "relu"))
    fl = 2 * 256 * 512 * 256
    lines.append(csv_line("kernel/fused_dense_256x512x256", t * 1e6,
                          f"gflops={fl / t / 1e9:.2f}"))

    from repro.kernels.block_matmul import ops as bm
    t = time_fn(lambda: bm.block_matmul(x, w, 4))
    lines.append(csv_line("kernel/block_matmul_256x512x256", t * 1e6,
                          f"gflops={fl / t / 1e9:.2f}"))

    from repro.kernels.decision_forest import ops as df
    xf = jnp.asarray(rng.standard_normal((512, 29)), jnp.float32)
    feat = jnp.asarray(rng.integers(0, 29, (50, 63)), jnp.int32)
    th = jnp.asarray(rng.standard_normal((50, 63)), jnp.float32)
    leaf = jnp.asarray(rng.standard_normal((50, 64)), jnp.float32)
    t = time_fn(lambda: df.forest_predict(xf, feat, th, leaf))
    lines.append(csv_line("kernel/forest_512rows_50trees_d6", t * 1e6,
                          f"rows_per_s={512 / t:.0f}"))

    from repro.kernels.flash_attention import ops as fa
    q = jnp.asarray(rng.standard_normal((1, 4, 256, 64)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 2, 256, 64)), jnp.float32)
    t = time_fn(lambda: fa.flash_attention(q, k, k, True))
    lines.append(csv_line("kernel/flash_attention_s256_h4", t * 1e6, ""))

    from repro.kernels.flash_decode import ops as fdec
    qd = jnp.asarray(rng.standard_normal((8, 4, 64)), jnp.float32)
    kd = jnp.asarray(rng.standard_normal((8, 1024, 64)), jnp.float32)
    t = time_fn(lambda: fdec.decode_attention(qd, kd, kd))
    lines.append(csv_line("kernel/flash_decode_s1024", t * 1e6, ""))
    return lines


if __name__ == "__main__":
    for ln in run():
        print(ln)
