"""Relational engine vs numpy oracle — unit + hypothesis property tests."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests degrade to skips
from hypothesis import given, settings, strategies as st

from repro.relational import ops, oracle
from repro.relational.table import Table


def mk_table(rng, n, with_vec=True):
    cols = {
        "id": jnp.arange(n, dtype=jnp.int32),
        "k": jnp.asarray(rng.integers(0, max(n // 3, 2), n), jnp.int32),
        "x": jnp.asarray(rng.random(n) * 10, jnp.float32),
    }
    if with_vec:
        cols["v"] = jnp.asarray(rng.standard_normal((n, 5)), jnp.float32)
    return Table.from_columns(cols)


def assert_tables_equal(t: Table, o, atol=1e-5):
    a = t.canonical()
    b = oracle.canonical(o)
    assert set(a) == set(b)
    for k in a:
        np.testing.assert_allclose(a[k], b[k], rtol=1e-4, atol=atol, err_msg=k)


def test_filter_and_compact():
    rng = np.random.default_rng(0)
    t = mk_table(rng, 50)
    mask = t["x"] > 5.0
    ft = ops.filter_(t, mask)
    npo = oracle.filter_(t.to_numpy(), np.asarray(mask))
    assert_tables_equal(ft, npo)
    ct = ops.compact(ft, 32)
    assert ct.capacity == 32
    assert_tables_equal(ct, npo)


def test_compact_up():
    rng = np.random.default_rng(1)
    t = mk_table(rng, 10)
    ct = ops.compact(t, 16)
    assert ct.capacity == 16
    assert_tables_equal(ct, t.to_numpy())


def test_fk_join():
    rng = np.random.default_rng(2)
    left = Table.from_columns({
        "fk": jnp.asarray(rng.integers(0, 12, 40), jnp.int32),
        "a": jnp.asarray(rng.random(40), jnp.float32)})
    right = Table.from_columns({
        "rid": jnp.arange(8, dtype=jnp.int32),
        "b": jnp.asarray(rng.random(8), jnp.float32)})
    j = ops.fk_join(left, right, "fk", "rid")
    npo = oracle.fk_join(left.to_numpy(), right.to_numpy(), "fk", "rid")
    assert_tables_equal(j, npo)


def test_fk_join_respects_invalid_right_rows():
    left = Table.from_columns({"fk": jnp.asarray([0, 1, 2], jnp.int32)})
    right = Table.from_columns({"rid": jnp.asarray([0, 1, 2], jnp.int32),
                                "b": jnp.asarray([1., 2., 3.], jnp.float32)},
                               valid=jnp.asarray([True, False, True]))
    j = ops.fk_join(left, right, "fk", "rid")
    out = j.canonical()
    np.testing.assert_array_equal(out["fk"], [0, 2])


def test_cross_join():
    rng = np.random.default_rng(3)
    a, b = mk_table(rng, 6, False), mk_table(rng, 4, False)
    b = b.rename({"id": "id2", "k": "k2", "x": "x2"})
    x = ops.cross_join(a, b)
    npo = oracle.cross_join(a.to_numpy(), b.to_numpy())
    assert_tables_equal(x, npo)


def test_aggregate():
    rng = np.random.default_rng(4)
    t = mk_table(rng, 60)
    g = ops.aggregate(t, "k", {"s": ("sum", "x"), "m": ("mean", "x"),
                               "c": ("count", "x"), "mx": ("max", "x"),
                               "mn": ("min", "x"), "vs": ("mean", "v")},
                      num_groups=64)
    npo = oracle.aggregate(t.to_numpy(), "k",
                           {"s": ("sum", "x"), "m": ("mean", "x"),
                            "c": ("count", "x"), "mx": ("max", "x"),
                            "mn": ("min", "x"), "vs": ("mean", "v")})
    assert_tables_equal(g, npo, atol=1e-4)


def test_aggregate_masked_rows_excluded():
    t = Table.from_columns({"k": jnp.asarray([0, 0, 1], jnp.int32),
                            "x": jnp.asarray([1., 100., 2.], jnp.float32)},
                           valid=jnp.asarray([True, False, True]))
    g = ops.aggregate(t, "k", {"s": ("sum", "x")}, num_groups=4)
    out = g.canonical()
    np.testing.assert_allclose(out["s"], [1.0, 2.0])


def test_union_all():
    rng = np.random.default_rng(5)
    a, b = mk_table(rng, 5), mk_table(rng, 7)
    u = ops.union_all(a, b)
    npo = oracle.union_all(a.to_numpy(), b.to_numpy())
    assert_tables_equal(u, npo)


# -- property tests ----------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(n=st.integers(4, 40), seed=st.integers(0, 1000),
       thresh=st.floats(0.0, 10.0))
def test_prop_filter_matches_oracle(n, seed, thresh):
    rng = np.random.default_rng(seed)
    t = mk_table(rng, n, with_vec=False)
    mask = t["x"] > thresh
    ft = ops.filter_(t, mask)
    npo = oracle.filter_(t.to_numpy(), np.asarray(mask))
    assert_tables_equal(ft, npo)


@settings(max_examples=20, deadline=None)
@given(n=st.integers(4, 30), m=st.integers(2, 10), seed=st.integers(0, 1000))
def test_prop_join_matches_oracle(n, m, seed):
    rng = np.random.default_rng(seed)
    left = Table.from_columns({
        "fk": jnp.asarray(rng.integers(0, m + 3, n), jnp.int32),
        "a": jnp.asarray(rng.random(n), jnp.float32)})
    right = Table.from_columns({
        "rid": jnp.arange(m, dtype=jnp.int32),
        "b": jnp.asarray(rng.random(m), jnp.float32)})
    j = ops.fk_join(left, right, "fk", "rid")
    npo = oracle.fk_join(left.to_numpy(), right.to_numpy(), "fk", "rid")
    assert_tables_equal(j, npo)


@settings(max_examples=20, deadline=None)
@given(n=st.integers(4, 50), seed=st.integers(0, 1000))
def test_prop_aggregate_matches_oracle(n, seed):
    rng = np.random.default_rng(seed)
    t = mk_table(rng, n, with_vec=False)
    g = ops.aggregate(t, "k", {"s": ("sum", "x"), "c": ("count", "x")},
                      num_groups=n + 2)
    npo = oracle.aggregate(t.to_numpy(), "k",
                           {"s": ("sum", "x"), "c": ("count", "x")})
    assert_tables_equal(g, npo, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(n=st.integers(2, 12), m=st.integers(2, 8), seed=st.integers(0, 100))
def test_prop_cross_join_cardinality(n, m, seed):
    rng = np.random.default_rng(seed)
    a = mk_table(rng, n, False)
    b = mk_table(rng, m, False).rename({"id": "i2", "k": "k2", "x": "x2"})
    x = ops.cross_join(a, b)
    assert x.capacity == n * m
    assert int(x.num_valid()) == n * m
