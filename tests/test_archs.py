"""Per-architecture smoke tests (assignment requirement): reduced config of
the same family, one forward/train step on CPU, asserting output shapes and
no NaNs — plus decode-vs-forward logit consistency per family."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, get_smoke_config
from repro.models import lm
from repro.train.optim import AdamW


def _batch(cfg, B=2, S=24, seed=0):
    rng = np.random.default_rng(seed)
    b = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
         "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)}
    if cfg.kind == "encdec":
        b["enc_embeds"] = jnp.asarray(
            rng.standard_normal((B, 12, cfg.d_model)), jnp.float32)
    return b


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_smoke_train_step(arch):
    cfg = get_smoke_config(arch)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    opt = AdamW(lr=1e-3)
    step = jax.jit(lm.make_train_step(cfg, opt))
    state = opt.init(params)
    batch = _batch(cfg)
    p2, s2, m = step(params, state, batch)
    l1 = float(m["loss"])
    _, _, m2 = step(p2, s2, batch)
    l2 = float(m2["loss"])
    assert np.isfinite(l1) and np.isfinite(l2)
    assert l2 < l1, f"{arch}: loss did not decrease ({l1} -> {l2})"
    h = lm.forward(params, cfg, batch["tokens"],
                   enc_embeds=batch.get("enc_embeds"))
    assert h.shape == (2, 24, cfg.d_model)
    assert np.isfinite(np.asarray(h, np.float32)).all()


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_smoke_decode_consistency(arch):
    """prefill(prompt) + decode(1 token) must reproduce forward()'s last
    logits exactly (f32)."""
    cfg = dataclasses.replace(get_smoke_config(arch), dtype="float32")
    params = lm.init_params(cfg, jax.random.PRNGKey(1))
    B, S = 2, 12
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    kw = {}
    if cfg.kind == "encdec":
        kw["enc_embeds"] = jnp.asarray(
            rng.standard_normal((B, 8, cfg.d_model)), jnp.float32)
    h = lm.forward(params, cfg, toks, **kw)
    logits_full = h[:, -1].astype(jnp.float32) @ params["embed"].T.astype(jnp.float32)
    _, cache = lm.prefill(params, cfg, toks[:, :S - 1], max_len=32, **kw)
    dec = lm.make_decode_step(cfg)
    logits_dec, cache2 = dec(params, cache, toks[:, S - 1])
    err = float(jnp.max(jnp.abs(logits_dec[:, :cfg.vocab]
                                - logits_full[:, :cfg.vocab])))
    assert err < 1e-2, f"{arch}: decode/forward mismatch {err}"
    assert int(cache2["len"]) == S


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_full_config_matches_assignment(arch):
    """The full configs carry the exact assigned hyperparameters."""
    cfg = get_config(arch)
    expected = {
        "granite-moe-1b-a400m": (24, 1024, 16, 8, 49155),
        "deepseek-v2-236b": (60, 5120, 128, 128, 102400),
        "xlstm-1.3b": (48, 2048, 4, 4, 50304),
        "nemotron-4-15b": (32, 6144, 48, 8, 256000),
        "stablelm-12b": (40, 5120, 32, 8, 100352),
        "granite-3-2b": (40, 2048, 32, 8, 49155),
        "deepseek-67b": (95, 8192, 64, 8, 102400),
        "seamless-m4t-medium": (12, 1024, 16, 16, 256206),
        "zamba2-1.2b": (38, 2048, 32, 32, 32000),
        "qwen2-vl-72b": (80, 8192, 64, 8, 152064),
    }[arch]
    assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
            cfg.vocab) == expected


def test_param_counts_plausible():
    """Sanity: analytic parameter counts near the advertised sizes."""
    approx = {
        "granite-3-2b": (2.0e9, 3.5e9),
        "deepseek-67b": (6.0e10, 7.5e10),
        "qwen2-vl-72b": (6.4e10, 8.2e10),
        "deepseek-v2-236b": (2.0e11, 2.6e11),
        "nemotron-4-15b": (1.2e10, 1.8e10),
    }
    for arch, (lo, hi) in approx.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n:.3e} outside [{lo:.1e},{hi:.1e}]"


def test_microbatched_train_step_matches_unbatched():
    cfg = dataclasses.replace(get_smoke_config("granite-3-2b"),
                              dtype="float32")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    opt = AdamW(lr=1e-3)
    batch = _batch(cfg, B=4, S=16)
    s1 = opt.init(params)
    p1, _, m1 = jax.jit(lm.make_train_step(cfg, opt, microbatches=1))(
        params, s1, batch)
    s2 = opt.init(params)
    p2, _, m2 = jax.jit(lm.make_train_step(cfg, opt, microbatches=2))(
        params, s2, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-4)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-3, atol=2e-3)
