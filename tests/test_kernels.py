"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import jax.numpy as jnp
import numpy as np
import pytest

rng = np.random.default_rng(0)


def _arr(shape, dtype=jnp.float32):
    return jnp.asarray(rng.standard_normal(shape), dtype)


@pytest.mark.parametrize("m,k,n", [(7, 12, 5), (130, 200, 70), (256, 512, 128),
                                   (1, 128, 128)])
@pytest.mark.parametrize("act", ["identity", "relu", "sigmoid", "gelu",
                                 "squared_relu"])
def test_fused_dense(m, k, n, act):
    from repro.kernels.fused_dense import ops, ref
    x, w, b = _arr((m, k)), _arr((k, n)), _arr((n,))
    np.testing.assert_allclose(ops.fused_dense(x, w, b, act),
                               ref.fused_dense(x, w, b, act),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_dense_dtypes(dtype):
    from repro.kernels.fused_dense import ops, ref
    x, w, b = _arr((64, 96), dtype), _arr((96, 32), dtype), _arr((32,), dtype)
    tol = 1e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(ops.fused_dense(x, w, b, "relu"), np.float32),
        np.asarray(ref.fused_dense(x, w, b, "relu"), np.float32),
        rtol=tol, atol=tol)


@pytest.mark.parametrize("m,k,n,t", [(10, 16, 40, 4), (130, 300, 520, 8),
                                     (64, 512, 1024, 16)])
def test_block_matmul(m, k, n, t):
    from repro.kernels.block_matmul import ops, ref
    x, w = _arr((m, k)), _arr((k, n))
    np.testing.assert_allclose(ops.block_matmul(x, w, t),
                               ref.block_matmul(x, w, t),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("n,d,t,depth", [(20, 8, 4, 3), (150, 16, 10, 5),
                                         (64, 29, 25, 6)])
def test_decision_forest(n, d, t, depth):
    from repro.kernels.decision_forest import ops, ref
    x = _arr((n, d))
    nn = 2 ** depth - 1
    feat = jnp.asarray(rng.integers(0, d, (t, nn)), jnp.int32)
    th = _arr((t, nn))
    leaf = _arr((t, 2 ** depth))
    np.testing.assert_allclose(ops.forest_predict(x, feat, th, leaf),
                               ref.forest_predict(x, feat, th, leaf),
                               rtol=1e-4, atol=1e-4)


def test_forest_matches_mlfuncs_atom():
    """Kernel path (R4-2 backend='pallas') == jnp atom path."""
    from repro.mlfuncs import builders
    fn = builders.decision_forest("f", 8, 4, 12, seed=3)
    atom = fn.graph.nodes[0].atom
    x = _arr((40, 12))
    y_jnp = atom.apply(x)
    import dataclasses
    atom_p = dataclasses.replace(atom, backend="pallas")
    y_pl = atom_p.apply(x)
    np.testing.assert_allclose(y_jnp, y_pl, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("b,hq,hkv,s,d", [(2, 4, 2, 37, 16), (1, 8, 8, 256, 64),
                                          (2, 6, 3, 100, 32)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention(b, hq, hkv, s, d, causal):
    from repro.kernels.flash_attention import ops, ref
    q, k, v = _arr((b, hq, s, d)), _arr((b, hkv, s, d)), _arr((b, hkv, s, d))
    got = ops.flash_attention(q, k, v, causal)
    kk = jnp.repeat(k, hq // hkv, 1).reshape(b * hq, s, d)
    vv = jnp.repeat(v, hq // hkv, 1).reshape(b * hq, s, d)
    want = ref.attention(q.reshape(b * hq, s, d), kk, vv, causal)
    np.testing.assert_allclose(got.reshape(b * hq, s, d), want,
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("bh,g,d,s", [(4, 6, 32, 300), (2, 8, 64, 1024),
                                      (1, 1, 16, 50)])
def test_flash_decode(bh, g, d, s):
    from repro.kernels.flash_decode import ops, ref
    q, k, v = _arr((bh, g, d)), _arr((bh, s, d)), _arr((bh, s, d))
    np.testing.assert_allclose(ops.decode_attention(q, k, v),
                               ref.decode_attention(q, k, v),
                               rtol=2e-4, atol=2e-4)


def test_flash_decode_shard_merge():
    """Partial (acc, m, l) merged across cache shards == full softmax —
    the correctness basis of the S-sharded decode (O3 on the KV cache)."""
    from repro.kernels.flash_decode import ops, ref
    bh, g, d, s = 3, 4, 32, 384
    q, k, v = _arr((bh, g, d)), _arr((bh, s, d)), _arr((bh, s, d))
    want = ref.decode_attention(q, k, v)
    splits = [(0, 128), (128, 256), (256, 384)]
    accs, ms, ls = [], [], []
    for lo, hi in splits:
        a, m, l = ops.decode_partials(q, k[:, lo:hi], v[:, lo:hi])
        accs.append(a)
        ms.append(m)
        ls.append(l)
    merged = ref.merge_partials(accs, ms, ls)
    np.testing.assert_allclose(merged, want, rtol=2e-4, atol=2e-4)


def test_fused_dense_atom_backend_swap():
    """R4-2's physical replacement: jnp vs pallas fused_dense atoms agree."""
    import dataclasses
    from repro.mlfuncs.functions import Atom
    w, b = _arr((24, 48)), _arr((48,))
    a_jnp = Atom("fused_dense", {"w": w, "b": b, "act": "relu"})
    a_pl = dataclasses.replace(a_jnp, backend="pallas")
    x = _arr((20, 24))
    np.testing.assert_allclose(a_jnp.apply(x), a_pl.apply(x),
                               rtol=1e-4, atol=1e-4)
