import os
import sys

# keep the default single CPU device for smoke tests / benches — the 512-way
# mesh is exclusive to launch/dryrun.py (assignment requirement)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
