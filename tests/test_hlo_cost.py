"""Trip-count-aware HLO cost model (launch/hlo_cost.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import hlo_cost


def _flops(fn, *args):
    c = jax.jit(fn).lower(*args).compile()
    return hlo_cost.analyze(c.as_text())


def test_plain_matmul():
    x = jnp.ones((64, 128))
    w = jnp.ones((128, 256))
    r = _flops(lambda a, b: a @ b, x, w)
    assert r["flops"] == pytest.approx(2 * 64 * 128 * 256, rel=0.05)


def test_scan_multiplies_trip_count():
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=7)
        return y.sum()
    x = jnp.ones((64, 128), jnp.bfloat16)
    w = jnp.ones((128, 128), jnp.bfloat16)
    r = _flops(f, x, w)
    assert r["flops"] == pytest.approx(7 * 2 * 64 * 128 * 128, rel=0.05)
    assert r["unknown_loops"] == 0


def test_nested_scans():
    def g(x, w):
        def outer(c, _):
            def inner(c2, _):
                return c2 @ w, None
            c2, _ = jax.lax.scan(inner, c, None, length=3)
            return c2, None
        y, _ = jax.lax.scan(outer, x, None, length=4)
        return y.sum()
    x = jnp.ones((64, 128))
    w = jnp.ones((128, 128))
    r = _flops(g, x, w)
    assert r["flops"] == pytest.approx(12 * 2 * 64 * 128 * 128, rel=0.05)


def test_collective_parse():
    from repro.launch.hlo_stats import collective_bytes
    hlo = """
ENTRY %main (p: f32[8,16]) -> f32[8,16] {
  %p = f32[8,16]{1,0} parameter(0)
  ROOT %ar = f32[8,16]{1,0} all-reduce(%p), replica_groups={}
}
"""
    out = collective_bytes(hlo)
    assert out["all-reduce"] == 8 * 16 * 4
