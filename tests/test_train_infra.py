"""Fault tolerance: checkpoint/restart resume, preemption, elastic re-mesh
planning, straggler watchdog, gradient compression, data pipeline."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.data.tokens import TokenPipeline
from repro.train import checkpoint as ckpt
from repro.train import compress, elastic
from repro.train.loop import train
from repro.train.optim import AdamW
from repro.train.stragglers import PreemptionGuard, StragglerWatchdog


def test_checkpoint_roundtrip(tmp_path):
    cfg = get_smoke_config("granite-3-2b")
    from repro.models import lm
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    opt = AdamW()
    state = (params, opt.init(params), (3, 17))
    path = ckpt.save(str(tmp_path), 5, state, cfg=cfg)
    assert os.path.exists(os.path.join(path, "manifest.json"))
    restored, step = ckpt.restore(str(tmp_path), state, cfg=cfg)
    assert step == 5
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_config_mismatch_refused(tmp_path):
    cfg = get_smoke_config("granite-3-2b")
    from repro.models import lm
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    ckpt.save(str(tmp_path), 1, params, cfg=cfg)
    other = dataclasses.replace(cfg, d_ff=cfg.d_ff * 2)
    with pytest.raises(ValueError, match="hash mismatch"):
        ckpt.restore(str(tmp_path), params, cfg=other)


def test_checkpoint_retention(tmp_path):
    cfg = get_smoke_config("granite-3-2b")
    from repro.models import lm
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    for s in range(1, 6):
        ckpt.save(str(tmp_path), s, params, cfg=cfg, keep=2)
    steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(steps) == 2 and steps[-1] == "step_00000005"


def test_train_resume_bit_identical(tmp_path):
    """Uninterrupted 6-step run == 3 steps + kill + resume for 3 more."""
    cfg = get_smoke_config("granite-3-2b")
    full = train(cfg, steps=6, batch=2, seq=16, seed=3)
    d = str(tmp_path / "ck")
    part1 = train(cfg, steps=3, batch=2, seq=16, seed=3, ckpt_dir=d,
                  ckpt_every=3)
    part2 = train(cfg, steps=6, batch=2, seq=16, seed=3, ckpt_dir=d,
                  ckpt_every=3)
    assert part2.resumed_from == 3
    np.testing.assert_allclose(full.losses[3:], part2.losses, rtol=1e-5)


def test_preemption_checkpoints_and_stops(tmp_path):
    cfg = get_smoke_config("granite-3-2b")
    guard = PreemptionGuard(install=False)

    def hook(step, m):
        if step == 2:
            guard.trigger()

    d = str(tmp_path / "ck")
    res = train(cfg, steps=100, batch=2, seq=16, ckpt_dir=d, ckpt_every=1000,
                guard=guard, hook=hook)
    assert res.preempted
    assert ckpt.latest_step(d) == 3  # saved at the preempted step


def test_elastic_plan():
    assert elastic.plan_new_mesh(512, 16) == (32, 16, 0)
    assert elastic.plan_new_mesh(480, 16) == (30, 16, 0)   # lost 2 hosts
    assert elastic.plan_new_mesh(250, 16) == (15, 16, 10)  # idle remainder
    assert elastic.plan_new_mesh(8, 16) == (1, 8, 0)       # tiny survivor set


def test_straggler_watchdog_evicts_and_reassigns():
    wd = StragglerWatchdog(n_hosts=4, threshold=1.5, strikes_to_act=2)
    normal = {0: 1.0, 1: 1.0, 2: 1.0, 3: 1.0}
    assert wd.observe(normal) == []
    slow = {0: 1.0, 1: 1.0, 2: 1.0, 3: 5.0}
    assert wd.observe(slow) == []          # first strike
    assert wd.observe(slow) == [3]         # second strike -> evict
    shards = {0: [0, 1], 1: [2, 3], 2: [4, 5], 3: [6, 7]}
    out = wd.reassignment(shards)
    assert 3 not in out
    assert sorted(x for v in out.values() for x in v) == list(range(8))


def test_gradient_compression_error_feedback():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.standard_normal((64, 64)), jnp.float32)
    grads = {"w": g}
    err = compress.init_error(grads)
    (q, s), err = compress.compress_tree(grads, err)
    deq = compress.decompress_tree((q, s))
    rel = float(jnp.linalg.norm(deq["w"] - g) / jnp.linalg.norm(g))
    assert rel < 0.02  # int8 quantization error bound
    # error feedback: accumulated (deq + err) recovers g exactly
    np.testing.assert_allclose(np.asarray(deq["w"] + err["w"]),
                               np.asarray(g), rtol=1e-5, atol=1e-6)


def test_compressed_psum_shard_map():
    devs = jax.devices()
    mesh = jax.sharding.Mesh(np.array(devs[:1]), ("data",))
    g = {"w": jnp.ones((8, 8), jnp.float32) * 0.5}
    err = compress.init_error(g)

    def f(grads, err):
        return compress.compressed_psum(grads, err, "data")

    from jax.sharding import PartitionSpec as P
    try:
        from jax import shard_map  # jax >= 0.6
    except ImportError:
        from jax.experimental.shard_map import shard_map
    out, err2 = shard_map(f, mesh=mesh,
                          in_specs=(P(), P()), out_specs=(P(), P()))(g, err)
    np.testing.assert_allclose(np.asarray(out["w"]), 0.5, rtol=1e-2)


def test_token_pipeline_determinism_and_sharding():
    p1 = TokenPipeline(vocab=100, batch=8, seq=16, seed=1)
    p2 = TokenPipeline(vocab=100, batch=8, seq=16, seed=1)
    b1, b2 = p1.next_batch(), p2.next_batch()
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # disjoint host shards
    h0 = TokenPipeline(vocab=100, batch=8, seq=16, seed=1, host_id=0,
                       num_hosts=2)
    h1 = TokenPipeline(vocab=100, batch=8, seq=16, seed=1, host_id=1,
                       num_hosts=2)
    a, b = h0.next_batch(), h1.next_batch()
    assert a["tokens"].shape == (4, 16)
    assert not np.array_equal(a["tokens"], b["tokens"])
    # seekability (checkpoint/restore)
    st = p1.state()
    nxt = p1.next_batch()
    p1.restore(st)
    np.testing.assert_array_equal(p1.next_batch()["tokens"], nxt["tokens"])


def test_loss_goes_down_over_short_run():
    cfg = get_smoke_config("granite-3-2b")
    res = train(cfg, steps=12, batch=4, seq=32, lr=3e-3, seed=0)
    assert np.mean(res.losses[-3:]) < np.mean(res.losses[:3])
