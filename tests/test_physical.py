"""Physical layer: lowering equivalence vs the reference interpreter,
pipeline fusion, backend-parameterized unified evaluator (np == jnp), and
the purity of the logical IR (no physical fields on logical nodes)."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import evaluator, executor, ir
from repro.core import physical as ph
from repro.core.lowering import lower
from repro.core.rules import ALL_RULES
from repro.data import workloads
from repro.mlfuncs import builders
from repro.mlfuncs.registry import Registry
from repro.relational.table import Table


def assert_canonical_close(a, b, label=""):
    assert set(a) == set(b), f"{label}: schema {sorted(set(a) ^ set(b))}"
    for k in a:
        np.testing.assert_allclose(a[k], b[k], rtol=5e-4, atol=5e-4,
                                   err_msg=f"{label}:{k}")


# ---------------------------------------------------------------------------
# lowered execution == reference interpreter, all 12 workload templates
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(workloads.ALL_WORKLOADS))
def test_lowering_equivalence(name):
    w = workloads.ALL_WORKLOADS[name](scale=0.3)
    ref = executor.execute_reference(w.plan, w.catalog).canonical()
    out = ph.run(lower(w.plan, w.catalog), dict(w.catalog.tables)).canonical()
    assert_canonical_close(ref, out, name)
    # explicit backend override must not change results
    out_jnp = ph.run(lower(w.plan, w.catalog, backend="jnp"),
                     dict(w.catalog.tables)).canonical()
    assert_canonical_close(ref, out_jnp, f"{name}/backend=jnp")


def test_lowering_equivalence_after_physical_rules():
    """R3-1/R3-2 annotate the side table; R4-2 re-realizes via the side
    table; lowered execution must stay equivalent throughout."""
    w = workloads.analytics_q1(scale=0.3)
    base = executor.execute_reference(w.plan, w.catalog).canonical()
    plan = w.plan
    cfgs = ALL_RULES["R3-2"].configs(plan, w.catalog)
    assert cfgs, "R3-2 must apply to the forest workload"
    plan = ALL_RULES["R3-2"].apply(plan, w.catalog, cfgs[0])
    assert plan.phys, "R3-2 must annotate the physical side table"
    assert_canonical_close(base, executor.execute(plan, w.catalog).canonical(),
                           "R3-2")
    mode_cfgs = [c for c in ALL_RULES["R4-2"].configs(plan, w.catalog)
                 if c.get("kind") == "mode"]
    assert mode_cfgs, "R4-2 must offer relational->fused on the annotated node"
    plan2 = ALL_RULES["R4-2"].apply(plan, w.catalog, mode_cfgs[0])
    assert plan2.root is plan.root, "R4-2 mode change must not touch the tree"
    assert plan2.signature() != plan.signature()
    assert_canonical_close(base, executor.execute(plan2, w.catalog).canonical(),
                           "R4-2")


# ---------------------------------------------------------------------------
# pipeline fusion
# ---------------------------------------------------------------------------

def test_filter_project_chains_fuse_into_one_pipeline():
    w = workloads.analytics_q1(scale=0.3)  # Project(Filter(Filter(Scan)))
    # tree-order lowering: this test pins the fusion mechanics; the costed
    # path may additionally insert compaction stages (tests/test_costed_*)
    pplan = lower(w.plan, w.catalog, costed=False)
    root = pplan.root
    assert isinstance(root, ph.PPipeline)
    assert isinstance(root.child, ph.PScan)
    kinds = [type(s).__name__ for s in root.stages]
    # source-to-sink order: the two filters run before the project
    assert kinds == ["FilterStage", "FilterStage", "ProjectStage"]

    def count(node):
        return sum(count(c) for c in node.children()) + (
            1 if isinstance(node, ph.PPipeline) else 0)

    assert count(root) == 1


def test_pipeline_fusion_stops_at_blocking_operators():
    w = workloads.rec_q1(scale=0.3)  # joins/aggregate/crossjoin in the middle
    pplan = lower(w.plan, w.catalog, costed=False)

    def walk(node):
        yield node
        for c in node.children():
            yield from walk(c)

    nodes = list(walk(pplan.root))
    assert any(isinstance(n, ph.PCrossJoin) for n in nodes)
    assert any(isinstance(n, ph.PAggregate) for n in nodes)
    for n in nodes:
        if isinstance(n, ph.PPipeline):
            assert not isinstance(n.child, ph.PPipeline), "maximal fusion"


# ---------------------------------------------------------------------------
# unified evaluator: np backend == jnp backend
# ---------------------------------------------------------------------------

def _expr_battery():
    age = ir.Col("age")
    genre = ir.Col("genre")
    vec = ir.Col("v")
    return [
        ir.Const(3.5),
        ir.BinOp("+", age, ir.Const(1.0)),
        ir.BinOp("/", age, ir.Const(0.0)),          # guarded division
        ir.BinOp("*", vec, age),                    # vector x scalar align
        ir.Cmp(">", age, ir.Const(40.0)),
        ir.Cmp("==", genre, ir.Const(2.0)),
        ir.BoolOp("and", (ir.Cmp(">", age, ir.Const(20.0)),
                          ir.Cmp("<", age, ir.Const(60.0)))),
        ir.BoolOp("not", (ir.Cmp(">", age, ir.Const(40.0)),)),
        ir.IsIn(genre, (1, 3)),
        ir.IfExpr(ir.Cmp(">", age, ir.Const(40.0)), age,
                  ir.BinOp("-", ir.Const(0.0), age)),
    ]


def test_unified_evaluator_np_matches_jnp():
    rng = np.random.default_rng(0)
    cols = {"age": rng.uniform(18, 80, 32).astype(np.float32),
            "genre": rng.integers(0, 5, 32).astype(np.int32),
            "v": rng.standard_normal((32, 4)).astype(np.float32)}
    t = Table.from_columns(cols)
    reg = Registry()
    for i, e in enumerate(_expr_battery()):
        a = evaluator.eval_expr(e, cols, reg, xp=np)
        b = np.asarray(evaluator.eval_expr(e, t, reg, xp=jnp))
        np.testing.assert_allclose(np.broadcast_to(a, b.shape), b,
                                   rtol=1e-5, atol=1e-6, err_msg=f"expr {i}")


def test_unified_evaluator_np_matches_jnp_on_workload_predicates():
    """Scan-level call-free predicates of every workload template evaluate
    identically under both array namespaces."""
    checked = 0
    for name in sorted(workloads.ALL_WORKLOADS):
        w = workloads.ALL_WORKLOADS[name](scale=0.3)
        for node in ir.walk(w.plan.root):
            if not (isinstance(node, ir.Filter) and isinstance(node.child, ir.Scan)
                    and not evaluator.has_call(node.pred)):
                continue
            npt = w.catalog.np_tables[node.child.table]
            tbl = w.catalog.tables[node.child.table]
            a = evaluator.eval_expr(node.pred, npt, w.plan.registry, xp=np)
            b = np.asarray(evaluator.eval_expr(node.pred, tbl, w.plan.registry))
            np.testing.assert_array_equal(np.broadcast_to(a, b.shape), b,
                                          err_msg=f"{name}")
            checked += 1
    assert checked >= 3


def test_const_evaluates_to_scalar():
    reg = Registry()
    v = evaluator.eval_expr(ir.Const(2.5), {}, reg)
    assert getattr(v, "ndim", None) == 0  # no (capacity,) materialization
    t = Table.from_columns({"x": jnp.arange(8, dtype=jnp.float32)})
    col = evaluator.as_column(v, t.capacity)
    assert col.shape == (8,)


def test_call_expr_np_namespace():
    reg = Registry()
    reg.register(builders.ffnn("f", [4, 8, 1], seed=0))
    x = np.random.default_rng(1).standard_normal((16, 4)).astype(np.float32)
    e = ir.Call("f", (ir.Col("x"),))
    a = evaluator.eval_expr(e, {"x": x}, reg, xp=np)
    b = evaluator.eval_expr(e, Table.from_columns({"x": x}), reg, xp=jnp)
    assert isinstance(a, np.ndarray)
    np.testing.assert_allclose(a, np.asarray(b), rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# logical IR purity
# ---------------------------------------------------------------------------

def test_logical_nodes_carry_no_physical_fields():
    for cls in (ir.BlockedMatmul, ir.ForestRelational):
        names = {f.name for f in dataclasses.fields(cls)}
        assert not names & {"mode", "backend", "n_tiles"}, cls


def test_phys_annotation_survives_subtree_rewrites():
    """Rewrites below an annotated node rebuild it via with_children; the
    uid (and thus the side-table annotation) must survive."""
    node = ir.ForestRelational(ir.Scan("t"), x_col="x", out_col="y", fn="f")
    rebuilt = node.with_children((ir.Filter(ir.Scan("t"),
                                            ir.Cmp(">", ir.Col("x"),
                                                   ir.Const(0.0))),))
    assert rebuilt.uid == node.uid
    plan = ir.Plan(node, Registry(),
                   {node.uid: ir.PhysConfig(mode="relational")})
    assert plan.phys_for(rebuilt).mode == "relational"
