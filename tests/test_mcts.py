"""MCTS optimizers: vanilla finds improvements, reusable shares state across
queries (collision rate), strategies preserve results on real workloads."""
import numpy as np
import pytest

from repro.core.executor import execute
from repro.core.mcts import ReusableMCTS, VanillaMCTS, configure_action
from repro.core.planner import (STRATEGIES, analytic_cost_fn, optimize_greedy,
                                optimize_vanilla_mcts)
from repro.data import workloads, templates


@pytest.fixture(scope="module")
def rec_q1():
    return workloads.rec_q1(scale=0.4)


def test_configure_action_returns_best_config(rec_q1):
    w = rec_q1
    cost_fn = analytic_cost_fn(w.catalog)
    res = configure_action(w.plan, w.catalog, "R4-1-split", cost_fn)
    assert res is not None
    plan2, cfg = res
    assert cfg.rule == "R4-1-split"


def test_vanilla_mcts_improves_cost(rec_q1):
    w = rec_q1
    cost_fn = analytic_cost_fn(w.catalog, memory_budget=w.memory_budget)
    m = VanillaMCTS(w.catalog, cost_fn, iterations=25, seed=0)
    best, stats = m.optimize(w.plan)
    assert stats["speedup"] > 1.5
    # and the optimized plan is still correct
    a = execute(w.plan, w.catalog).canonical()
    b = execute(best, w.catalog).canonical()
    for k in a:
        np.testing.assert_allclose(a[k], b[k], rtol=5e-4, atol=5e-4)


@pytest.mark.parametrize("strategy", ["arbitrary", "heuristic", "greedy"])
def test_strategies_preserve_results(rec_q1, strategy):
    w = rec_q1
    fn = STRATEGIES[strategy]
    p2, _ = fn(w.plan, w.catalog, cost_fn=analytic_cost_fn(w.catalog),
               memory_budget=w.memory_budget)
    a = execute(w.plan, w.catalog).canonical()
    b = execute(p2, w.catalog).canonical()
    assert set(a) == set(b)
    for k in a:
        np.testing.assert_allclose(a[k], b[k], rtol=5e-4, atol=5e-4)


def test_reusable_mcts_state_sharing():
    """Two parameter-variants of the same template should collide in the
    embedding-keyed node store (the paper's 89% ID collision mechanism).
    Uses an untrained embedder — identical structure still embeds nearby."""
    from repro.core import optimizer as om
    emb = om.init_embedder(0)
    r = ReusableMCTS(
        catalog_fn=None, embed_fn=emb.embed,
        cost_fn_factory=lambda cat: analytic_cost_fn(cat),
        iterations=8, warm_iterations=3, sim_threshold=0.98, seed=0)
    p1, c1 = templates.sample_query(4, seed=1, scale=0.3)
    p2, c2 = templates.sample_query(4, seed=2, scale=0.3)
    out1, s1 = r.optimize(p1, c1)
    out2, s2 = r.optimize(p2, c2)
    assert not s1["collision"]
    assert s2["collision"], "same-template query should match the stored root"
    assert s2["iterations"] < s1["iterations"]
    assert r.collision_rate == 0.5
    assert r.storage_bytes() > 0


def test_reusable_mcts_correctness():
    from repro.core import optimizer as om
    emb = om.init_embedder(0)
    r = ReusableMCTS(catalog_fn=None, embed_fn=emb.embed,
                     cost_fn_factory=lambda cat: analytic_cost_fn(cat),
                     iterations=10, seed=1)
    plan, cat = templates.sample_query(11, seed=5, scale=0.3)
    best, stats = r.optimize(plan, cat)
    a = execute(plan, cat).canonical()
    b = execute(best, cat).canonical()
    for k in a:
        np.testing.assert_allclose(a[k], b[k], rtol=5e-4, atol=5e-4)
