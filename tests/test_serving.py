"""Serving tier: micro-batcher policy, QueryServer end-to-end, the
launch-server admit/step loop, and the signature-stats -> ReusableMCTS
warm-start feedback channel."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import executor, ir
from repro.core.mcts import ReusableMCTS
from repro.core.planner import analytic_cost_fn
from repro.data import templates, workloads
from repro.mlfuncs import builders
from repro.mlfuncs.registry import Registry
from repro.relational.table import Table
from repro.serving import (MicroBatcher, QueryRequest, QueryServer, feedback)


def _mini(seed=0, n=32):
    rng = np.random.default_rng(seed)
    t = Table.from_columns({
        "id": jnp.arange(n, dtype=jnp.int32),
        "x": jnp.asarray(rng.uniform(0, 10, n), jnp.float32),
        "f": jnp.asarray(rng.standard_normal((n, 8)), jnp.float32)})
    cat = ir.Catalog()
    cat.add("t", t)
    reg = Registry()
    reg.register(builders.ffnn("m", [8, 16, 1], seed=1))
    root = ir.Project(
        ir.Filter(ir.Scan("t"), pred=ir.Cmp(">", ir.Col("x"), ir.Const(3.0))),
        outputs=(("score", ir.Call("m", (ir.Col("f"),))),),
        keep=("id",))
    return ir.Plan(root, reg), cat


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


# ---------------------------------------------------------------------------
# micro-batcher admission policy
# ---------------------------------------------------------------------------

def _req(rid, key, t):
    return QueryRequest(rid=rid, plan=None, catalog=None, tables={},
                        key=key, submit_t=t)


def test_batcher_dispatches_full_group_immediately():
    b = MicroBatcher(max_batch_size=2, max_wait_s=1.0)
    b.add(_req(0, "sig_a", 0.0))
    assert b.pop_ready(now=0.0) == []          # under size, under deadline
    b.add(_req(1, "sig_a", 0.0))
    ready = b.pop_ready(now=0.0)
    assert len(ready) == 1 and len(ready[0]) == 2
    assert b.pending() == 0


def test_batcher_deadline_flushes_partial_group():
    b = MicroBatcher(max_batch_size=8, max_wait_s=0.5)
    b.add(_req(0, "sig_a", 0.0))
    b.add(_req(1, "sig_b", 0.3))
    assert b.pop_ready(now=0.4) == []
    ready = b.pop_ready(now=0.6)               # only sig_a's deadline passed
    assert [r.key for r in ready[0].requests] == ["sig_a"]
    assert b.pending() == 1
    ready = b.pop_ready(now=0.9)
    assert ready[0].requests[0].key == "sig_b"


def test_batcher_groups_by_signature_and_splits_oversize():
    b = MicroBatcher(max_batch_size=2, max_wait_s=10.0)
    for i in range(5):
        b.add(_req(i, "sig_a" if i % 2 == 0 else "sig_b", 0.0))
    ready = b.pop_ready(now=0.0)               # 3x sig_a -> one full batch
    assert len(ready) == 2                     # sig_a[2] + sig_b[2]
    assert all(len(batch) == 2 for batch in ready)
    assert {batch.key for batch in ready} == {"sig_a", "sig_b"}
    assert b.pending() == 1                    # sig_a remainder waits
    assert len(b.pop_all()) == 1


# ---------------------------------------------------------------------------
# query server end-to-end
# ---------------------------------------------------------------------------

def test_query_server_batches_same_signature_and_results_match():
    clock = FakeClock()
    srv = QueryServer(max_batch_size=4, max_wait_s=0.01, clock=clock)
    reqs = []
    for s in range(6):
        plan, cat = _mini(seed=s)              # fresh build, same signature
        reqs.append(srv.submit(plan, cat))
    assert srv.pending() == 6 and not any(r.done for r in reqs)
    assert srv.step() == 4                     # one full micro-batch
    clock.t = 0.02
    assert srv.step() == 2                     # deadline flush
    assert all(r.done for r in reqs)
    assert all(r.batch_size >= 2 for r in reqs)

    # one signature, two dispatches, two traces (one per batch size)
    assert len(srv.signatures) == 1
    sig = next(iter(srv.signatures.values()))
    assert sig.requests == 6 and sig.dispatches == 2
    assert sig.mean_occupancy == 3.0
    assert srv.cache.traces == 2

    # every batched result equals its per-request reference execution
    for s, r in enumerate(reqs):
        ref = executor.execute(*_mini(seed=s))
        np.testing.assert_allclose(r.result.canonical()["score"],
                                   ref.canonical()["score"],
                                   rtol=1e-5, atol=1e-6)


def test_query_server_drain_and_singleton_batch():
    srv = QueryServer(max_batch_size=8, max_wait_s=100.0)
    plan, cat = _mini(seed=0)
    req = srv.submit(plan, cat)
    assert srv.step() == 0                     # neither full nor overdue
    assert srv.drain() == 1
    assert req.done and req.batch_size == 1
    # singleton used the plain cached executable (no B=1 vmap variant)
    assert srv.cache.stats.misses == 1
    ref = executor.execute(*_mini(seed=0))
    np.testing.assert_allclose(req.result.canonical()["score"],
                               ref.canonical()["score"], rtol=1e-5, atol=1e-6)


def test_query_server_distinct_signatures_never_mix():
    srv = QueryServer(max_batch_size=4, max_wait_s=0.0)
    pa, ca = _mini(seed=0)
    other = ir.Plan(ir.Filter(ir.Scan("t"),
                              pred=ir.Cmp(">", ir.Col("x"), ir.Const(5.0))),
                    pa.registry)
    ra = srv.submit(pa, ca)
    rb = srv.submit(other, ca)
    assert ra.key != rb.key
    srv.drain()
    assert len(srv.signatures) == 2
    assert ra.batch_size == 1 and rb.batch_size == 1


def test_query_server_failed_dispatch_marks_requests_not_hangs():
    """A payload whose shapes disagree with the signature's schema fails
    its own micro-batch: every request comes back done-with-error, later
    traffic still serves, and the loop survives."""
    srv = QueryServer(max_batch_size=4, max_wait_s=0.0)
    plan, cat = _mini(seed=0)
    good = srv.submit(plan, cat)
    bad_tables = {"t": Table.from_columns(
        {"id": jnp.arange(7, dtype=jnp.int32),
         "x": jnp.zeros((7,), jnp.float32),
         "f": jnp.zeros((7, 8), jnp.float32)})}
    bad = srv.submit(plan, cat, bad_tables)     # same key, wrong capacity
    srv.drain()
    assert good.done and bad.done
    assert good.error is not None and bad.error is not None
    assert srv.failed == 2 and srv.pending() == 0
    sig = next(iter(srv.signatures.values()))
    assert sig.failures == 2

    # the server still serves well-formed traffic afterwards
    ok = srv.submit(plan, cat)
    srv.drain()
    assert ok.done and ok.error is None and ok.result is not None
    assert srv.completed == 1


def test_mean_occupancy_counts_only_served_requests():
    """Regression: occupancy used to divide *submitted* requests (including
    still-pending ones) by dispatches, over-reporting occupancy to the MCTS
    feedback channel whenever requests sat in the batcher."""
    clock = FakeClock()
    srv = QueryServer(max_batch_size=2, max_wait_s=100.0, clock=clock)
    plan, cat = _mini(seed=0)
    for _ in range(3):
        srv.submit(plan, cat)
    assert srv.step() == 2                     # full pair; third stays queued
    sig = next(iter(srv.signatures.values()))
    assert sig.requests == 3 and sig.served_requests == 2
    assert sig.dispatches == 1
    assert sig.mean_occupancy == 2.0           # not 3.0: one never rode
    assert sig.as_dict()["mean_occupancy"] == 2.0
    srv.drain()
    assert sig.served_requests == 3 and sig.mean_occupancy == 1.5


def test_mean_occupancy_ignores_failed_batches():
    """Failed submissions never rode a dispatch either: they must not count
    toward occupancy (they are tracked as failures instead)."""
    srv = QueryServer(max_batch_size=4, max_wait_s=0.0)
    plan, cat = _mini(seed=0)
    bad_tables = {"t": Table.from_columns(
        {"id": jnp.arange(7, dtype=jnp.int32),
         "x": jnp.zeros((7,), jnp.float32),
         "f": jnp.zeros((7, 8), jnp.float32)})}
    srv.submit(plan, cat)                      # good (capacity 32) ...
    srv.submit(plan, cat, bad_tables)          # ... + bad (7): batch fails
    srv.drain()
    srv.submit(plan, cat)
    srv.drain()                                # 1 served, 1 dispatch
    sig = next(iter(srv.signatures.values()))
    assert sig.requests == 3 and sig.failures == 2
    assert sig.served_requests == 1 and sig.dispatches == 1
    assert sig.mean_occupancy == 1.0           # not 3.0


def test_mean_wait_s_reaches_stats_and_feedback_payload():
    """Regression: total_wait_s was accumulated but never exported — the
    queueing-pressure signal has to reach as_dict() and the feedback
    channel's SignatureExport for warm-start prioritization to see it."""
    clock = FakeClock()
    srv = QueryServer(max_batch_size=2, max_wait_s=100.0, clock=clock)
    plan, cat = _mini(seed=0)
    srv.submit(plan, cat)                      # submit_t = 0.0
    clock.t = 0.5
    srv.submit(plan, cat)                      # submit_t = 0.5, pair is full
    assert srv.step() == 2                     # dispatches at t = 0.5
    sig = next(iter(srv.signatures.values()))
    assert sig.total_wait_s == pytest.approx(0.5)
    assert sig.mean_wait_s == pytest.approx(0.25)
    assert sig.as_dict()["mean_wait_s"] == pytest.approx(0.25)
    exports = feedback.export_signature_stats(srv)
    assert exports[0].mean_wait_s == pytest.approx(0.25)
    # queueing pressure raises the signature's optimizer priority
    assert exports[0].weight >= exports[0].requests * exports[0].mean_wait_s


def test_dispatch_and_finish_share_one_timebase():
    """Regression: dispatch_t used to be the *caller's* earlier clock read
    while dt was measured from the executor's own later one, skewing
    finish_t - dispatch_t against the measured dispatch duration. Both
    timestamps now bracket the dispatch on the executor's clock."""

    class TickingClock:
        def __init__(self, step=0.125):
            self.t, self.step = 0.0, step

        def __call__(self):
            self.t += self.step
            return self.t

    clock = TickingClock()
    srv = QueryServer(max_batch_size=2, max_wait_s=1e9, clock=clock)
    plan, cat = _mini(seed=0)
    reqs = [srv.submit(plan, cat) for _ in range(2)]
    assert srv.step() == 2
    sig = next(iter(srv.signatures.values()))
    assert sig.dispatches == 1
    for r in reqs:
        # the executor measured dt between its own two clock reads and
        # stamped both ends of exactly that interval
        assert (r.finish_t - r.dispatch_t) == pytest.approx(
            sig.total_dispatch_s)
        assert r.dispatch_t >= r.submit_t      # single monotonic timebase
        assert r.queue_wait_s == pytest.approx(r.dispatch_t - r.submit_t)
        assert r.latency_s == pytest.approx(r.finish_t - r.submit_t)


# ---------------------------------------------------------------------------
# feedback channel: server stats -> optimizer warm-start (fixed seeds)
# ---------------------------------------------------------------------------

def test_server_feedback_warm_starts_optimizer():
    from repro.core import optimizer as om
    emb = om.init_embedder(0)

    def mk():
        return ReusableMCTS(catalog_fn=None, embed_fn=emb.embed,
                            cost_fn_factory=lambda cat: analytic_cost_fn(cat),
                            iterations=16, warm_iterations=4,
                            sim_threshold=0.98, seed=0)

    variant = templates.sample_query(1, seed=2, scale=0.3)
    cold = mk()
    _, s_cold = cold.optimize(*variant)
    assert s_cold["iterations"] == 16 and not s_cold["collision"]

    # the server sees repeated parameterized traffic of the template-1 family
    srv = QueryServer(max_batch_size=4, max_wait_s=0.0)
    for i in range(6):
        plan, cat = templates.sample_query(1, seed=1, scale=0.3)
        srv.submit(plan, cat, workloads.roll_tables(dict(cat.tables), i))
    srv.drain()

    exports = feedback.export_signature_stats(srv)
    assert len(exports) == 1
    assert exports[0].requests == 6 and exports[0].mean_dispatch_s > 0.0

    warm = mk()
    summary = feedback.warm_start_from_server(warm, exports, top_k=1)
    assert len(summary["primed"]) == 1 and summary["store_nodes"] > 0

    _, s_warm = warm.optimize(*variant)
    # warm run collided with the primed root, replayed its best rule chain,
    # and reached an as-good-or-better plan in a quarter of the iterations
    assert s_warm["collision"] and s_warm["replayed"]
    assert s_warm["iterations"] < s_cold["iterations"]
    assert s_warm["best_cost"] <= s_cold["best_cost"] * 1.05
    assert s_warm["speedup"] > 1.5


# ---------------------------------------------------------------------------
# launch-server (LM decode) admit/step smoke test
# ---------------------------------------------------------------------------

def test_launch_server_admit_and_step_smoke():
    from repro.configs import get_smoke_config
    from repro.launch import serve

    cfg = get_smoke_config("granite-3-2b")
    server = serve.Server(cfg, batch=2, max_len=32)
    rng = np.random.default_rng(0)
    reqs = [serve.Request(rid=i,
                          prompt=rng.integers(1, cfg.vocab, 3),
                          max_new=2)
            for i in range(3)]
    assert server.free_slots == 2
    assert server.admit(reqs[0]) and server.admit(reqs[1])
    assert server.free_slots == 0
    assert not server.admit(reqs[2])           # full: admission refused

    bound = serve.max_decode_steps(reqs[:2])
    finished = steps = 0
    while finished < 2 and steps <= bound:
        finished += server.step()
        steps += 1
    assert finished == 2
    assert all(r.done for r in reqs[:2])
    assert all(len(r.out) == len(r.prompt) + r.max_new for r in reqs[:2])
    assert server.free_slots == 2
    assert server.admit(reqs[2])               # slots were recycled
