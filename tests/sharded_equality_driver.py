"""Standalone sharded-equivalence checker (run in a fresh process).

Proves, for every one of the 12 workload templates under a forced
multi-device host mesh, that the three realizations of the same micro-batch
— N sequential dispatches of the plain cached executable, one single-device
vmapped dispatch (``get_or_compile_batched``), and one multi-device sharded
dispatch (``get_or_compile_sharded``) — agree pairwise: valid masks and
integer columns **exactly** (same rows survive, same keys/votes/ids), float
columns to the 2e-5 tolerance the batched-equivalence tests established in
PR 2. Bitwise float equality across the three is not a stable property:
XLA fuses/reassociates reductions differently per traced batch shape (B,
B/ways, unbatched), which perturbs a few workloads by ~1 float32 ulp —
direction and victim vary with compiler version and thread layout.

Runs as ``__main__`` in a subprocess because the 8-device host platform
must be forced via XLA_FLAGS *before* jax initializes its backend — the
parent pytest process has usually already initialized a 1-device CPU.
``tests/test_serving_sharded.py`` spawns it with the right environment; it
can also be run by hand:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python tests/sharded_equality_driver.py
"""
from __future__ import annotations

import sys

SCALE = 0.25
BATCH = 8
MIN_DEVICES = 8


def check_workload(name: str, mesh, batch: int = BATCH) -> None:
    import jax.numpy as jnp
    import numpy as np

    from repro.core.plan_cache import PlanCache
    from repro.data import workloads

    w = workloads.ALL_WORKLOADS[name](scale=SCALE)
    plan, catalog = w.plan, w.catalog
    tabs = workloads.rolled_instances(dict(catalog.tables), batch)

    cache = PlanCache()
    run_seq = cache.get_or_compile(plan, catalog)
    seq = [run_seq(t) for t in tabs]
    bat = cache.get_or_compile_batched(plan, catalog, batch)(tuple(tabs))
    shd = cache.get_or_compile_sharded(plan, catalog, batch, mesh)(tuple(tabs))

    # the sharded entry must be its own compilation, not a fallback hit on
    # the batched one (otherwise sharded == batched is vacuous)
    assert cache.traces == 3, f"{name}: expected 3 traces, got {cache.traces}"

    def agree(a, b, what):
        np.testing.assert_array_equal(np.asarray(a.valid), np.asarray(b.valid),
                                      err_msg=f"{what}.valid")
        for k in a.columns:
            av, bv = np.asarray(a[k]), np.asarray(b[k])
            if np.issubdtype(av.dtype, np.floating):
                np.testing.assert_allclose(av, bv, rtol=2e-5, atol=2e-5,
                                           err_msg=f"{what}.{k}")
            else:
                np.testing.assert_array_equal(av, bv, err_msg=f"{what}.{k}")

    for i in range(batch):
        s, b, h = seq[i], bat[i], shd[i]
        assert set(h.columns) == set(s.columns) == set(b.columns)
        agree(h, b, f"{name}[{i}] sharded vs batched")
        agree(h, s, f"{name}[{i}] sharded vs sequential")
        agree(b, s, f"{name}[{i}] batched vs sequential")


def check_server(mesh, batch: int = BATCH) -> None:
    """The serving tier picks the sharded executable for eligible batches
    (one full group -> one sharded dispatch, results matching the vmapped
    program) and falls back to the batched one for a remainder the device
    count doesn't divide."""
    import numpy as np

    from repro.core.plan_cache import PlanCache
    from repro.data import workloads
    from repro.serving import QueryServer

    w = workloads.ALL_WORKLOADS["simple_q1"](scale=SCALE)
    base = dict(w.catalog.tables)
    srv = QueryServer(max_batch_size=batch, max_wait_s=3600.0, mesh=mesh)
    reqs = [srv.submit(w.plan, w.catalog, workloads.roll_tables(base, i))
            for i in range(batch)]
    assert srv.step() == batch                  # one full group, one dispatch
    assert srv.executor.sharded_dispatches == 1
    assert all(r.done and r.error is None and r.batch_size == batch
               for r in reqs)
    ref_cache = PlanCache()
    run_bat = ref_cache.get_or_compile_batched(w.plan, w.catalog, batch)
    refs = run_bat(tuple(workloads.roll_tables(base, i)
                         for i in range(batch)))
    for r, ref in zip(reqs, refs):
        np.testing.assert_array_equal(np.asarray(r.result.valid),
                                      np.asarray(ref.valid))
        for k in ref.columns:
            np.testing.assert_allclose(np.asarray(r.result[k]),
                                       np.asarray(ref[k]),
                                       rtol=2e-5, atol=2e-5, err_msg=k)

    # a 3-request remainder: 3 doesn't divide the device count -> batched
    rest = [srv.submit(w.plan, w.catalog, workloads.roll_tables(base, i))
            for i in range(3)]
    assert srv.drain() == 3
    assert all(r.done and r.error is None for r in rest)
    assert srv.executor.sharded_dispatches == 1  # unchanged: fallback path
    assert srv.stats()["sharded_dispatches"] == 1


def main() -> int:
    import jax

    from repro.core import mesh as mesh_util
    from repro.data import workloads

    n = len(jax.devices())
    if n < MIN_DEVICES:
        print(f"FAIL: need >= {MIN_DEVICES} devices, have {n} "
              f"(set XLA_FLAGS=--xla_force_host_platform_device_count=8)")
        return 2
    mesh = mesh_util.data_mesh(MIN_DEVICES)
    assert mesh_util.can_shard(mesh, BATCH)
    for name in sorted(workloads.ALL_WORKLOADS):
        check_workload(name, mesh)
        print(f"{name}: OK", flush=True)
    print(f"all {len(workloads.ALL_WORKLOADS)} workloads: "
          f"sharded == batched == sequential")
    check_server(mesh)
    print("server: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
