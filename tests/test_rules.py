"""Every co-optimization rule must preserve query results (O1-O4) —
per-config equivalence + chained-rewrite equivalence + hypothesis random
rule sequences."""
import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis")  # property tests degrade to skips
from hypothesis import given, settings, strategies as st

from repro.relational.table import Table
from repro.mlfuncs import builders
from repro.mlfuncs.registry import Registry
from repro.core import ir
from repro.core.executor import execute
from repro.core.rules import ALL_RULES


@pytest.fixture(scope="module")
def setup():
    rng = np.random.default_rng(0)
    N, M = 40, 16
    users = Table.from_columns({
        "user_id": jnp.arange(N, dtype=jnp.int32),
        "age": jnp.asarray(rng.integers(18, 80, N), jnp.float32),
        "user_f": jnp.asarray(rng.standard_normal((N, 12)), jnp.float32)})
    movies = Table.from_columns({
        "movie_id": jnp.arange(M, dtype=jnp.int32),
        "genre": jnp.asarray(rng.integers(0, 5, M), jnp.int32),
        "movie_f": jnp.asarray(rng.standard_normal((M, 8)), jnp.float32)})
    cat = ir.Catalog()
    cat.add("users", users)
    cat.add("movies", movies)
    reg = Registry()
    reg.register(builders.two_tower("tt", [12, 16, 8], [8, 16, 8], seed=1))
    trend = builders.ffnn("trend", [8, 8, 1], seed=2)
    trend.selectivity_hint = 0.5
    reg.register(trend)
    reg.register(builders.concat_ffnn("cf", [12, 8], [16, 1], seed=3))
    reg.register(builders.decision_forest("forest", 6, 3, 12, seed=4))
    reg.register(builders.autoencoder_encoder("ae", 12, 4096, 4, seed=5))
    reg.register(builders.kmeans_assign("km", 4, 12, seed=6))
    root = ir.Project(
        child=ir.Filter(
            child=ir.Filter(
                child=ir.CrossJoin(ir.Scan("users"), ir.Scan("movies")),
                pred=ir.IsIn(ir.Col("genre"), (1, 2, 3))),
            pred=ir.Cmp(">", ir.Call("trend", (ir.Col("movie_f"),)),
                        ir.Const(0.4))),
        outputs=(("score", ir.Call("tt", (ir.Col("user_f"), ir.Col("movie_f")))),
                 ("cscore", ir.Call("cf", (ir.Col("user_f"), ir.Col("movie_f")))),
                 ("fpred", ir.Call("forest", (ir.Col("user_f"),))),
                 ("enc", ir.Call("ae", (ir.Col("user_f"),))),
                 ("cluster", ir.Call("km", (ir.Col("user_f"),)))),
        keep=("user_id", "movie_id"))
    plan = ir.Plan(root, reg)
    base = execute(plan, cat).canonical()
    return plan, cat, base


def check_equal(a, b, label=""):
    assert set(a) == set(b), f"{label}: schema {sorted(set(a) ^ set(b))}"
    for k in a:
        assert a[k].shape == b[k].shape, f"{label}:{k} shape"
        np.testing.assert_allclose(a[k], b[k], rtol=5e-4, atol=5e-4,
                                   err_msg=f"{label}:{k}")


@pytest.mark.parametrize("rule_name", sorted(ALL_RULES))
def test_rule_preserves_results(setup, rule_name):
    plan, cat, base = setup
    rule = ALL_RULES[rule_name]
    cfgs = rule.configs(plan, cat)
    for cfg in cfgs[:6]:
        p2 = rule.apply(plan, cat, cfg)
        out = execute(p2, cat).canonical()
        check_equal(base, out, f"{rule_name} {dict(cfg.params)}")


def test_rules_have_coverage(setup):
    """The representative query must exercise most of the action space."""
    plan, cat, _ = setup
    applicable = {n for n, r in ALL_RULES.items() if r.configs(plan, cat)}
    assert {"R1-1", "R1-2", "R1-4-merge", "R2-1", "R3-1", "R3-2", "R3-3",
            "R4-1-fuse", "R4-1-split", "R4-2"} <= applicable


def test_chained_split_pushdown(setup):
    """Paper Fig. 4: split two-tower, push towers below the cross join."""
    plan, cat, base = setup
    for _ in range(2):
        cfgs = ALL_RULES["R4-1-split"].configs(plan, cat)
        if not cfgs:
            break
        plan = ALL_RULES["R4-1-split"].apply(plan, cat, cfgs[0])
    for rn in ["R1-2", "R1-3"]:
        for _ in range(8):
            cfgs = ALL_RULES[rn].configs(plan, cat)
            if not cfgs:
                break
            plan = ALL_RULES[rn].apply(plan, cat, cfgs[0])
    out = execute(plan, cat).canonical()
    check_equal(base, out, "chained")


def test_unfuse_roundtrip(setup):
    plan, cat, base = setup
    cfgs = ALL_RULES["R4-1-fuse"].configs(plan, cat)
    plan2 = ALL_RULES["R4-1-fuse"].apply(plan, cat, cfgs[0])
    cfgs2 = ALL_RULES["R4-1-unfuse"].configs(plan2, cat)
    assert cfgs2
    plan3 = ALL_RULES["R4-1-unfuse"].apply(plan2, cat, cfgs2[0])
    check_equal(base, execute(plan3, cat).canonical(), "fuse/unfuse")


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_prop_random_rule_sequences(setup, seed):
    """Random sequences of rule applications never change results."""
    plan, cat, base = setup
    rng = np.random.default_rng(seed)
    names = sorted(ALL_RULES)
    cur = plan
    for _ in range(4):
        name = names[int(rng.integers(0, len(names)))]
        cfgs = ALL_RULES[name].configs(cur, cat)
        if not cfgs:
            continue
        cfg = cfgs[int(rng.integers(0, len(cfgs)))]
        cur = ALL_RULES[name].apply(cur, cat, cfg)
    out = execute(cur, cat).canonical()
    check_equal(base, out, f"seq seed={seed}")
