"""Sharded execution path: mesh utilities, eligibility/fallback policy,
cache-key distinctness, serving-tier integration, and (via a subprocess
with a forced 8-device host platform) sharded == batched == sequential on
all 12 workload templates."""
import os
import subprocess
import sys

import jax
import pytest

from repro.core import ir, mesh as mesh_util
from repro.core.lowering import lower
from repro.core import physical as ph
from repro.core.plan_cache import PlanCache
from repro.data import workloads

SCALE = 0.25


# ---------------------------------------------------------------------------
# mesh utility layer
# ---------------------------------------------------------------------------

def test_data_mesh_shape_and_signature():
    mesh = mesh_util.data_mesh()
    assert mesh.axis_names == ("data",)
    assert mesh_util.batch_ways(mesh) == len(jax.devices())
    assert mesh_util.mesh_signature(mesh) == f"data={len(jax.devices())}"
    one = mesh_util.data_mesh(1)
    assert mesh_util.batch_ways(one) == 1
    with pytest.raises(ValueError):
        mesh_util.data_mesh(len(jax.devices()) + 1)
    with pytest.raises(ValueError):
        mesh_util.data_mesh(0)
    with pytest.raises(ValueError):
        # an unrecognized axis name would silently never shard anything
        mesh_util.data_mesh(1, axis="batch")


def test_can_shard_policy():
    """Eligibility == models.sharding's divisibility-fitting policy AND more
    than one device: single-device meshes and non-dividing batch sizes are
    never sharded."""
    assert not mesh_util.can_shard(None, 8)
    one = mesh_util.data_mesh(1)
    assert not mesh_util.can_shard(one, 8)       # 1 device: nothing to split
    if len(jax.devices()) >= 2:
        two = mesh_util.data_mesh(2)
        assert mesh_util.can_shard(two, 4)       # 4 % 2 == 0
        assert not mesh_util.can_shard(two, 3)   # 3 % 2 != 0
        assert not mesh_util.can_shard(two, 1)   # batch < ways


def test_lower_sharded_backend_resolves_nodes_to_jnp():
    """backend='sharded' is a plan-level realization: per-node it must
    resolve to the pure-XLA path (each device runs an ordinary program on
    its slice), overriding even an explicit pallas annotation."""
    import jax.numpy as jnp
    import numpy as np
    from repro.mlfuncs.functions import Atom, MLGraph, MLNode, MLFunction
    from repro.mlfuncs.registry import Registry
    from repro.relational.table import Table

    rng = np.random.default_rng(0)
    t = Table.from_columns({
        "id": jnp.arange(8, dtype=jnp.int32),
        "f": jnp.asarray(rng.standard_normal((8, 4)), jnp.float32)})
    cat = ir.Catalog()
    cat.add("t", t)
    reg = Registry()
    w = rng.standard_normal((4, 4)).astype(np.float32)
    reg.register(MLFunction("mm", graph=MLGraph(
        [MLNode(0, Atom("matmul", {"w": w}), (("in", 0),))], 0, 1)))
    bm = ir.BlockedMatmul(ir.Scan("t"), x_col="f", out_col="y", fn="mm")
    plan = ir.Plan(bm, reg, phys={
        bm.uid: ir.PhysConfig(mode="fused", backend="pallas", n_tiles=2)})
    pplan = lower(plan, cat, backend="sharded")
    (node,) = [n for n in _walk_phys(pplan.root)
               if isinstance(n, ph.PBlockedMatmul)]
    assert node.backend == "jnp"
    # mode and tiling annotations survive the backend override
    assert node.mode == "fused" and node.n_tiles == 2


def _walk_phys(node):
    yield node
    for c in node.children():
        yield from _walk_phys(c)


# ---------------------------------------------------------------------------
# plan-cache sharded entry: fallback + key distinctness
# ---------------------------------------------------------------------------

def test_sharded_ineligible_falls_back_to_batched_entry():
    """A single-device mesh (or a batch the device count doesn't divide)
    must reuse the *batched* executable under its own key — no duplicate
    compilation, no phantom sharded cache entry."""
    w = workloads.ALL_WORKLOADS["simple_q1"](scale=SCALE)
    cache = PlanCache()
    mesh = mesh_util.data_mesh(1)
    fb = cache.get_or_compile_sharded(w.plan, w.catalog, 2, mesh)
    assert cache.stats.misses == 1 and len(cache._cache) == 1
    f2 = cache.get_or_compile_batched(w.plan, w.catalog, 2)
    assert f2 is fb and cache.stats.hits == 1
    # the fallback really executes: results match the sequential program
    tabs = workloads.rolled_instances(dict(w.catalog.tables), 2)
    outs = fb(tuple(tabs))
    assert len(outs) == 2
    with pytest.raises(ValueError):
        cache.get_or_compile_sharded(w.plan, w.catalog, 0, mesh)


@pytest.mark.skipif(len(jax.devices()) < 2, reason="needs >= 2 devices "
                    "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")
def test_sharded_key_is_first_class():
    """An eligible mesh compiles a distinct executable whose key records
    backend=sharded + mesh shape; same mesh shape re-hits it."""
    w = workloads.ALL_WORKLOADS["simple_q1"](scale=SCALE)
    cache = PlanCache()
    mesh = mesh_util.data_mesh(2)
    fsh = cache.get_or_compile_sharded(w.plan, w.catalog, 2, mesh)
    fbat = cache.get_or_compile_batched(w.plan, w.catalog, 2)
    assert fsh is not fbat and cache.stats.misses == 2
    assert any("#be=sharded" in k and "#mesh=data=2" in k
               for k in cache._cache._data)
    again = cache.get_or_compile_sharded(w.plan, w.catalog, 2,
                                         mesh_util.data_mesh(2))
    assert again is fsh and cache.stats.hits == 1
    with pytest.raises(ValueError):
        fsh(tuple(workloads.rolled_instances(dict(w.catalog.tables), 3)))


# ---------------------------------------------------------------------------
# serving tier without a mesh: nothing shards
# ---------------------------------------------------------------------------

def test_server_without_mesh_never_shards():
    from repro.serving import QueryServer
    w = workloads.ALL_WORKLOADS["simple_q1"](scale=SCALE)
    srv = QueryServer(max_batch_size=2, max_wait_s=3600.0)
    base = dict(w.catalog.tables)
    for i in range(2):
        srv.submit(w.plan, w.catalog, workloads.roll_tables(base, i))
    assert srv.step() == 2
    st = srv.stats()
    assert st["sharded_dispatches"] == 0 and st["dispatches"] == 1


@pytest.mark.skipif(len(jax.devices()) < 2, reason="needs >= 2 devices "
                    "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")
def test_explicit_backend_override_disables_sharding():
    """backend='jnp'/'pallas' is an explicit node-level kernel choice; the
    sharded realization lowers per-node to jnp, so a mesh must not silently
    override the caller's backend on grouped traffic."""
    from repro.serving import QueryServer
    w = workloads.ALL_WORKLOADS["simple_q1"](scale=SCALE)
    mesh = mesh_util.data_mesh(2)
    srv = QueryServer(max_batch_size=2, max_wait_s=3600.0,
                      backend="jnp", mesh=mesh)
    base = dict(w.catalog.tables)
    reqs = [srv.submit(w.plan, w.catalog, workloads.roll_tables(base, i))
            for i in range(2)]
    assert srv.step() == 2
    assert all(r.done and r.error is None for r in reqs)
    st = srv.stats()
    assert st["sharded_dispatches"] == 0 and st["dispatches"] == 1
    # the compiled entry carries the override, not the sharded realization
    assert any("#be=jnp" in k for k in srv.cache._cache._data)
    assert not any("#be=sharded" in k for k in srv.cache._cache._data)


# ---------------------------------------------------------------------------
# the full multi-device proof, in a fresh 8-device process
# ---------------------------------------------------------------------------

def _forced_device_env(n: int = 8):
    env = dict(os.environ)
    flags = [t for t in env.get("XLA_FLAGS", "").split()
             if "--xla_force_host_platform_device_count" not in t]
    flags.append(f"--xla_force_host_platform_device_count={n}")
    env["XLA_FLAGS"] = " ".join(flags)
    env["JAX_PLATFORMS"] = "cpu"
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = os.path.abspath(src) + os.pathsep + env.get(
        "PYTHONPATH", "")
    return env


def test_sharded_equals_batched_and_sequential_all_workloads_8dev():
    """Spawns ``tests/sharded_equality_driver.py`` under a forced 8-device
    host platform (the parent process has usually already initialized a
    1-device jax backend, so the flag must be set in a fresh process): on
    every workload the sharded, vmapped, and sequential realizations agree
    pairwise — masks and integer columns exactly, float columns to the
    established vmap-fusion tolerance — and the serving tier picks the
    sharded executable for eligible batches and falls back for the rest."""
    driver = os.path.join(os.path.dirname(__file__),
                          "sharded_equality_driver.py")
    proc = subprocess.run([sys.executable, driver], env=_forced_device_env(),
                          capture_output=True, text=True, timeout=1500)
    assert proc.returncode == 0, (
        f"driver failed\nstdout:\n{proc.stdout}\nstderr:\n{proc.stderr}")
    assert "all 12 workloads" in proc.stdout
    assert "server: OK" in proc.stdout
