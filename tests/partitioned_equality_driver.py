"""Standalone partitioned-operator equivalence checker (fresh process).

Proves, under a forced 8-device host mesh, that intra-query-partitioned
physical plans (the PartSpec layer: PCrossJoin by left rows, PJoin by probe
rows or hash bucket, pipelines/ML nodes by row block, with explicit
PRepartition collectives) equal the single-device reference on every one of
the 12 workload templates — valid masks and integer columns exactly, float
columns to the established 2e-5 tolerance — in BOTH partitioning flavors
(maximal row-block; hash-bucketed joins where a join exists). Also checks:

* skewed joins: all keys in one hash bucket, empty buckets, non-dividing
  row counts (the static-shape soundness corners of bucket partitioning);
* an R3-rewritten plan (BlockedMatmul/ForestRelational nodes) partitioned
  by row block;
* the memory-budget path end to end: a per-device budget below rec_q1's
  unpartitioned ``phys_peak_memory`` makes costed lowering select a
  partitioned plan that fits, and ``QueryServer`` serves the oversized
  query through ``get_or_compile_partitioned`` with the PartSpec vector
  visible in ``PlanCache.key()``.

Runs as ``__main__`` in a subprocess because the 8-device host platform
must be forced via XLA_FLAGS *before* jax initializes its backend.
``tests/test_partitioned.py`` spawns it; by hand:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python tests/partitioned_equality_driver.py
"""
from __future__ import annotations

import sys

SCALE = 0.25
MIN_DEVICES = 8


def _assert_tables_equal(ref, out, label):
    import numpy as np

    assert set(ref) == set(out), f"{label}: schema {set(ref) ^ set(out)}"
    for k in ref:
        a, b = ref[k], out[k]
        assert a.shape == b.shape, f"{label}:{k} {a.shape} vs {b.shape}"
        if np.issubdtype(a.dtype, np.integer) or a.dtype == bool:
            np.testing.assert_array_equal(a, b, err_msg=f"{label}:{k}")
        else:
            np.testing.assert_allclose(a, b, rtol=2e-5, atol=2e-5,
                                       err_msg=f"{label}:{k}")


def _run_partitioned(pplan, tables, mesh):
    import jax

    from repro.core import mesh as mesh_util
    from repro.core import physical as ph

    fn = mesh_util.shard_replicated(
        lambda t: ph.run(pplan, t, axis=mesh_util.DATA_AXIS), mesh)
    return jax.jit(fn)(tables)


def check_workload(name: str, mesh, ways: int) -> None:
    """Both partitioning flavors of every workload equal the reference."""
    from repro.core import cost, executor, stage_graph
    from repro.data import workloads

    w = workloads.ALL_WORKLOADS[name](scale=SCALE)
    ref = executor.execute_reference(w.plan, w.catalog).canonical()
    profile = cost.DeviceProfile.detect()
    g = stage_graph.build(w.plan, w.catalog, profile=profile, ways=ways)
    part_sites = [s for s in g.sites.values() if s.kind == "part"]
    assert part_sites, f"{name}: no partition sites at ways={ways}"

    flavors = {"row": g.partitioned_decisions()}
    if any(len(s.options) > 2 for s in part_sites):  # joins offer hash too
        d = g.default_decisions()
        for s in part_sites:
            d[s.sid] = len(s.options) - 1  # hash for joins, row otherwise
        flavors["hash"] = d

    for flavor, d in flavors.items():
        pplan = g.realize(d)
        assert pplan.ways == ways and pplan.parts, (name, flavor)
        out = _run_partitioned(pplan, dict(w.catalog.tables), mesh).canonical()
        _assert_tables_equal(ref, out, f"{name}/{flavor}")
        print(f"{name}/{flavor}: OK", flush=True)


def check_r3_realizations(mesh, ways: int) -> None:
    """Row-block-partitioned PBlockedMatmul / PForestRelational (the R3
    rewrites' realizations) equal the reference."""
    from repro.core import cost, executor, stage_graph
    from repro.core.rules import ALL_RULES
    from repro.data import workloads

    profile = cost.DeviceProfile.detect()
    for name, rule in (("rec_q3", "R3-1"), ("analytics_q1", "R3-2")):
        w = workloads.ALL_WORKLOADS[name](scale=SCALE)
        cfgs = ALL_RULES[rule].configs(w.plan, w.catalog)
        assert cfgs, f"{rule} must apply to {name}"
        plan = ALL_RULES[rule].apply(w.plan, w.catalog, cfgs[0])
        ref = executor.execute_reference(plan, w.catalog).canonical()
        g = stage_graph.build(plan, w.catalog, profile=profile, ways=ways)
        pplan = g.realize(g.partitioned_decisions())
        from repro.core import physical as ph
        mls = [n for n in _walk(pplan.root)
               if isinstance(n, (ph.PBlockedMatmul, ph.PForestRelational))]
        assert mls, name
        out = _run_partitioned(pplan, dict(w.catalog.tables),
                               mesh).canonical()
        _assert_tables_equal(ref, out, f"{name}/{rule}/row")
        print(f"{name}/{rule}: OK", flush=True)


def _walk(node):
    yield node
    for c in node.children():
        yield from _walk(c)


def check_skewed_joins(mesh, ways: int) -> None:
    """Hash-bucketed PJoin and row-partitioned PJoin/PCrossJoin on
    adversarial key distributions: every key in one bucket, buckets with no
    keys, and row counts the device count doesn't divide."""
    import numpy as np
    import jax.numpy as jnp

    from repro.core import mesh as mesh_util
    from repro.core import physical as ph
    from repro.relational import ops
    from repro.relational.table import Table

    rng = np.random.default_rng(7)
    cases = {
        # 21 % 8 == 5: every key lands in bucket 5, one device does it all
        "all-one-bucket": np.full(37, 21, np.int32),
        # keys congruent 3 mod 8: buckets other than 3 stay empty
        "empty-buckets": (rng.integers(0, 3, 41) * 8 + 3).astype(np.int32),
        # plain non-uniform keys over a non-dividing row count
        "uniform-53": rng.integers(0, 100, 53).astype(np.int32),
    }
    for label, keys in cases.items():
        n = len(keys)
        lt = Table.from_columns(
            {"k": jnp.asarray(keys),
             "v": jnp.asarray(rng.standard_normal(n), jnp.float32)},
            valid=jnp.asarray(rng.random(n) < 0.8))
        rkeys = np.unique(np.concatenate(
            [keys, np.arange(6, dtype=np.int32)]))
        rt = Table.from_columns(
            {"rk": jnp.asarray(rkeys),
             "w": jnp.asarray(rng.standard_normal(len(rkeys)), jnp.float32)})
        tables = {"L": lt, "R": rt}
        ref = ops.fk_join(lt, rt, "k", "rk", "r_")
        blk = mesh_util.row_block(lt.capacity, ways)

        variants = {
            "hash": ph.PRepartition(
                ph.PJoin(
                    left=ph.PRepartition(ph.PScan("L"), op="bucket",
                                         ways=ways, in_capacity=lt.capacity,
                                         out_capacity=lt.capacity, key="k"),
                    right=ph.PRepartition(ph.PScan("R"), op="bucket",
                                          ways=ways, in_capacity=rt.capacity,
                                          out_capacity=rt.capacity,
                                          key="rk"),
                    left_key="k", right_key="rk", rprefix="r_"),
                op="combine", ways=ways, in_capacity=lt.capacity,
                out_capacity=lt.capacity),
            "row": ph.PRepartition(
                ph.PJoin(
                    left=ph.PRepartition(ph.PScan("L"), op="slice",
                                         ways=ways, in_capacity=lt.capacity,
                                         out_capacity=blk),
                    right=ph.PScan("R"),
                    left_key="k", right_key="rk", rprefix="r_"),
                op="allgather", ways=ways, in_capacity=blk,
                out_capacity=lt.capacity),
        }
        for flavor, root in variants.items():
            pplan = ph.PhysicalPlan(root=root, registry=None, ways=ways)
            out = _run_partitioned(pplan, tables, mesh)
            np.testing.assert_array_equal(np.asarray(ref.valid),
                                          np.asarray(out.valid),
                                          err_msg=f"{label}/{flavor}.valid")
            m = np.asarray(ref.valid)
            for c in ref.columns:  # invalid rows carry garbage: mask-aware
                np.testing.assert_allclose(
                    np.asarray(ref[c])[m], np.asarray(out[c])[m],
                    rtol=2e-5, atol=2e-5, err_msg=f"{label}/{flavor}.{c}")

        # row-partitioned cross join over the same non-dividing tables
        ref_x = ops.cross_join(lt, rt, "a_", "b_")
        root = ph.PRepartition(
            ph.PCrossJoin(
                left=ph.PRepartition(ph.PScan("L"), op="slice", ways=ways,
                                     in_capacity=lt.capacity,
                                     out_capacity=blk),
                right=ph.PScan("R"), aprefix="a_", bprefix="b_"),
            op="allgather", ways=ways, in_capacity=blk * rt.capacity,
            out_capacity=lt.capacity * rt.capacity)
        out = _run_partitioned(
            ph.PhysicalPlan(root=root, registry=None, ways=ways), tables,
            mesh)
        np.testing.assert_array_equal(np.asarray(ref_x.valid),
                                      np.asarray(out.valid),
                                      err_msg=f"{label}/xjoin.valid")
        m = np.asarray(ref_x.valid)
        for c in ref_x.columns:
            np.testing.assert_allclose(
                np.asarray(ref_x[c])[m], np.asarray(out[c])[m],
                rtol=2e-5, atol=2e-5, err_msg=f"{label}/xjoin.{c}")
        print(f"skew {label}: OK", flush=True)


def check_budgeted_serving(mesh, ways: int) -> None:
    """A per-device budget below the unpartitioned working set routes the
    oversized query through the partitioned path, end to end."""
    import numpy as np

    from repro.core import cost, costed_lowering, executor, stage_graph
    from repro.data import workloads
    from repro.serving import QueryServer

    w = workloads.ALL_WORKLOADS["retail_q3"](scale=SCALE)
    profile = cost.DeviceProfile.detect()
    g = stage_graph.build(w.plan, w.catalog, profile=profile, ways=ways)
    peak_rep = cost.phys_peak_memory(g.realize(g.default_decisions()),
                                     w.catalog, profile)
    peak_part = cost.phys_peak_memory(g.realize(g.partitioned_decisions()),
                                      w.catalog, profile)
    assert peak_part < peak_rep, (peak_part, peak_rep)
    budget = (peak_part + peak_rep) / 2.0

    # costed lowering under the budget picks a partitioned plan that fits
    low = costed_lowering.lower_costed(w.plan, w.catalog, profile=profile,
                                       memory_budget=budget, ways=ways)
    assert low.plan.ways == ways and low.plan.parts, low.signature
    assert low.peak_memory <= budget
    assert low.budget_pruned > 0 and not low.budget_pruned_all

    # ...and the server serves the oversized query through it
    srv = QueryServer(max_batch_size=4, max_wait_s=3600.0, mesh=mesh,
                      memory_budget=budget)
    req = srv.submit(w.plan, w.catalog)
    assert req.partitioned
    assert "#be=part" in req.key and "#mesh=" in req.key
    assert any(tok.startswith("pt") for tok in
               req.key.split("#cl=")[1].split(";")), req.key
    assert req.key == srv.cache.key(w.plan, w.catalog, mesh=mesh)
    assert srv.drain() == 1 and req.error is None, req.error
    assert srv.stats()["partitioned_dispatches"] == 1
    ref = executor.execute_reference(w.plan, w.catalog).canonical()
    _assert_tables_equal(ref, req.result.canonical(), "served-oversized")

    # repeated traffic of the signature hits the same compiled executable
    t0 = srv.cache.traces
    req2 = srv.submit(w.plan, w.catalog,
                      workloads.roll_tables(dict(w.catalog.tables), 1))
    assert srv.drain() == 1 and req2.error is None
    assert srv.cache.traces == t0, "warm partitioned dispatch re-traced"
    assert np.asarray(req2.result.valid).sum() > 0
    print("budgeted serving: OK", flush=True)


def main() -> int:
    import jax

    from repro.core import mesh as mesh_util
    from repro.data import workloads

    n = len(jax.devices())
    if n < MIN_DEVICES:
        print(f"FAIL: need >= {MIN_DEVICES} devices, have {n} "
              f"(set XLA_FLAGS=--xla_force_host_platform_device_count=8)")
        return 2
    mesh = mesh_util.data_mesh(MIN_DEVICES)
    ways = mesh_util.batch_ways(mesh)
    for name in sorted(workloads.ALL_WORKLOADS):
        check_workload(name, mesh, ways)
    print(f"all {len(workloads.ALL_WORKLOADS)} workloads: "
          f"partitioned == reference")
    check_r3_realizations(mesh, ways)
    check_skewed_joins(mesh, ways)
    check_budgeted_serving(mesh, ways)
    print("partitioned driver: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
