"""Partitioned physical operators (the PartSpec layer): decision sites,
boundary insertion, per-device costing/peak memory, memory-budget pruning,
cache/serving integration, and — in-process on a multi-device host and via
a subprocess with a forced 8-device platform — equality with the
single-device reference, including skewed joins."""
import dataclasses
import functools
import logging
import os
import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import cost, costed_lowering, stage_graph
from repro.core import mesh as mesh_util
from repro.core import physical as ph
from repro.core.lowering import lower
from repro.core.plan_cache import PlanCache
from repro.data import workloads
from repro.relational import ops
from repro.relational.table import Table

SCALE = 0.25
WAYS = 8  # partition sites are ways-parameterized, no devices needed


# ---------------------------------------------------------------------------
# mesh partition helpers
# ---------------------------------------------------------------------------

def test_row_block_and_padding():
    assert mesh_util.row_block(16, 8) == 2
    assert mesh_util.row_block(17, 8) == 3       # non-dividing: pad the tail
    assert mesh_util.padded_capacity(17, 8) == 24
    assert mesh_util.row_block(5, 8) == 1
    with pytest.raises(ValueError):
        mesh_util.row_block(8, 0)


def test_hash_bucket_is_stable_mod():
    b = np.asarray(mesh_util.hash_bucket(jnp.asarray([0, 7, 8, 21, -3]), 8))
    assert list(b) == [0, 7, 0, 5, 5]            # non-negative, key mod ways
    assert b.max() < 8


def test_partspec_signatures():
    assert ph.REPLICATED.signature() == "rep"
    assert ph.PartSpec(kind="row", ways=8).signature() == "row8"
    assert ph.PartSpec(kind="hash", ways=8, key="k").signature() == "hash8[k]"


def test_launch_mesh_reexports_core():
    from repro.launch import mesh as launch_mesh
    assert launch_mesh.make_host_mesh is mesh_util.make_host_mesh
    assert launch_mesh.make_production_mesh is mesh_util.make_production_mesh


# ---------------------------------------------------------------------------
# stage-graph partition sites + realization
# ---------------------------------------------------------------------------

def test_partition_sites_only_with_ways():
    w = workloads.rec_q1(scale=SCALE)
    g1 = stage_graph.build(w.plan, w.catalog,
                           profile=cost.DeviceProfile.detect())
    assert not any(s.kind == "part" for s in g1.sites.values())
    g8 = stage_graph.build(w.plan, w.catalog,
                           profile=cost.DeviceProfile.detect(), ways=WAYS)
    parts = [s for s in g8.sites.values() if s.kind == "part"]
    assert parts
    for s in parts:
        assert s.options[0] == ph.REPLICATED and s.default == 0
        assert s.options[1] == ph.PartSpec(kind="row", ways=WAYS)
    # the join site additionally offers the hash-bucket spec on its key
    assert any(len(s.options) > 2
               and s.options[2].kind == "hash" for s in parts)


def test_default_decisions_stay_tree_order_under_ways():
    """Opening partition sites must not move the default: realize(default)
    is still the exact tree-order physical plan (replicated everywhere, no
    boundaries, empty parts table)."""
    for name in ("rec_q1", "analytics_q1", "simple_q3"):
        w = workloads.ALL_WORKLOADS[name](scale=SCALE)
        g = stage_graph.build(w.plan, w.catalog,
                              profile=cost.DeviceProfile.detect(), ways=WAYS)
        pp = g.realize(g.default_decisions())
        tree = lower(w.plan, w.catalog, costed=False)
        assert pp.signature() == tree.signature()
        assert not pp.parts and pp.ways == 1


def test_partitioned_realize_inserts_boundaries_and_side_table():
    w = workloads.retail_q3(scale=SCALE)
    g = stage_graph.build(w.plan, w.catalog,
                          profile=cost.DeviceProfile.detect(), ways=WAYS)
    pp = g.realize(g.partitioned_decisions())
    reparts = [n for n in _walk(pp.root) if isinstance(n, ph.PRepartition)]
    assert reparts, "partitioned realization must insert boundaries"
    # the result table is replicated: the outermost boundary restores it
    assert isinstance(pp.root, ph.PRepartition)
    assert pp.root.op in ("allgather", "combine")
    assert pp.ways == WAYS
    assert pp.parts and all(s.kind != "rep" for s in pp.parts.values())
    assert pp.part_signature() != "rep"
    # side-table paths resolve: every recorded path names a real node
    for path in pp.parts:
        node = pp.root
        for seg in path.split(".")[1:]:
            node = node.children()[int(seg)]
    # partitioned plans refuse to run outside shard_map
    with pytest.raises(RuntimeError):
        ph.run(pp, dict(w.catalog.tables))


def test_row_partition_splits_pipeline_at_last_compact():
    """A row-partitioned pipeline with an inserted compact keeps the
    compact in a replicated prefix (per-block compaction would reorder
    rows) and partitions only the row-local suffix."""
    w = workloads.analytics_q1(scale=SCALE)
    g = stage_graph.build(w.plan, w.catalog,
                          profile=cost.DeviceProfile.detect(), ways=WAYS)
    d = g.partitioned_decisions()
    # force a compact in: pick the non-None option of some compact site
    compact_sites = [s for s in g.sites.values() if s.kind == "compact"]
    assert compact_sites
    for s in compact_sites:
        d[s.sid] = 1
    pp = g.realize(d)
    for node in _walk(pp.root):
        if isinstance(node, ph.PPipeline):
            has_compact = any(isinstance(st, ph.CompactStage)
                              for st in node.stages)
            if has_compact:  # the compact-bearing pipeline stays replicated
                assert not _under_row_partition(pp.root, node)


def _walk(node):
    yield node
    for c in node.children():
        yield from _walk(c)


def _under_row_partition(root, target):
    """True iff ``target`` executes on row blocks: the nearest repartition
    boundary *below* it (on the path to the scans) is a slice."""
    def path_to(n, t):
        if n is t:
            return [n]
        for c in n.children():
            p = path_to(c, t)
            if p is not None:
                return [n] + p
        return None

    below = path_to(root, target)[-1]
    for n in _walk(below):
        if isinstance(n, ph.PRepartition):
            return n.op == "slice"
    return False


# ---------------------------------------------------------------------------
# per-device costing + peak memory
# ---------------------------------------------------------------------------

def test_partitioned_peak_memory_below_replicated():
    """Row-partitioning the cross join bounds each device's working set by
    its block of the product — the whole point of the PartSpec layer."""
    profile = cost.DeviceProfile.detect()
    w = workloads.retail_q3(scale=SCALE)
    g = stage_graph.build(w.plan, w.catalog, profile=profile, ways=WAYS)
    peak_rep = cost.phys_peak_memory(g.realize(g.default_decisions()),
                                     w.catalog, profile)
    peak_part = cost.phys_peak_memory(g.realize(g.partitioned_decisions()),
                                      w.catalog, profile)
    assert peak_part < 0.5 * peak_rep, (peak_part, peak_rep)


def test_repartition_costs_price_collectives():
    """Boundary ops carry exchange volume and per-shard collective
    launches: a partitioned plan's cost strictly grows with the profile's
    collective_overhead_s (the satellite fix — a 0.0 default priced every
    collective as free)."""
    profile = cost.DeviceProfile.detect()
    assert profile.collective_overhead_s > 0  # non-zero per-backend prior
    w = workloads.retail_q3(scale=SCALE)
    g = stage_graph.build(w.plan, w.catalog, profile=profile, ways=WAYS)
    pp = g.realize(g.partitioned_decisions())
    ocs = cost.phys_op_costs(pp, w.catalog, profile)
    reparts = [oc for oc in ocs if oc.label.startswith("repart")]
    assert reparts and any(oc.n_coll == WAYS for oc in reparts)
    slow = dataclasses.replace(profile, collective_overhead_s=1.0)
    assert (cost.plan_cost(pp, w.catalog, slow)
            > cost.plan_cost(pp, w.catalog, profile))
    # breakdown surfaces the collective count for calibration
    b = cost.plan_cost_breakdown(pp, w.catalog, profile)
    assert b.n_coll >= WAYS


def test_fit_profile_calibrates_collective_overhead():
    """Samples with a non-zero n_coll column identify
    collective_overhead_s; without them it stays at the prior."""
    prior = cost.CPU_PROFILE
    b = cost.CostBreakdown(flops=1e6, hbm_bytes=1e4, param_bytes=0.0,
                           vmem_bytes=0.0, n_ops=2, seconds=0.0, n_coll=8.0)
    true_co = prior.collective_overhead_s * 50  # collective-dominated device

    def t(x):
        return (x.flops / prior.peak_flops + x.hbm_bytes / prior.hbm_bw
                + x.n_ops * prior.op_overhead_s + x.n_coll * true_co)

    samples = [(s, t(s), 1.0) for s in
               (b, dataclasses.replace(b, n_coll=32.0),
                dataclasses.replace(b, n_coll=64.0))]
    fit = cost.fit_profile(samples, prior)
    assert fit.mape_after < fit.mape_before
    assert fit.profile.collective_overhead_s > prior.collective_overhead_s * 5
    # all-zero n_coll column: the coefficient stays at the prior
    b0 = dataclasses.replace(b, n_coll=0.0)
    fit0 = cost.fit_profile([(b0, t(b0), 1.0)], prior)
    assert fit0.profile.collective_overhead_s == pytest.approx(
        prior.collective_overhead_s, rel=0.2)


def test_profile_signature_tracks_budget_and_collectives():
    a = cost.DeviceProfile.detect()
    assert a.signature() != dataclasses.replace(
        a, collective_overhead_s=a.collective_overhead_s * 2).signature()
    assert a.signature() != dataclasses.replace(
        a, memory_budget=1e6).signature()


# ---------------------------------------------------------------------------
# memory-budget pruning in costed lowering
# ---------------------------------------------------------------------------

def test_budget_selects_partitioned_plan_that_fits():
    profile = cost.DeviceProfile.detect()
    w = workloads.retail_q3(scale=SCALE)
    g = stage_graph.build(w.plan, w.catalog, profile=profile, ways=WAYS)
    peak_rep = cost.phys_peak_memory(g.realize(g.default_decisions()),
                                     w.catalog, profile)
    low = costed_lowering.lower_costed(w.plan, w.catalog, profile=profile,
                                       memory_budget=peak_rep * 0.6,
                                       ways=WAYS)
    assert low.plan.ways == WAYS and low.plan.parts
    assert low.peak_memory <= peak_rep * 0.6
    assert low.budget_pruned > 0 and not low.budget_pruned_all
    assert low.memory_budget == peak_rep * 0.6


def test_budget_pruning_all_candidates_is_loud(caplog):
    """A budget nothing can fit (smaller than a base table) must fall back
    to tree order AND say so — in the decision record and the log — not
    silently degrade (the satellite fix)."""
    w = workloads.simple_q1(scale=SCALE)
    with caplog.at_level(logging.WARNING,
                         logger="repro.core.costed_lowering"):
        low = costed_lowering.lower_costed(
            w.plan, w.catalog, profile=cost.DeviceProfile.detect(),
            memory_budget=64.0, ways=WAYS)
    assert low.budget_pruned_all
    assert low.budget_pruned == low.candidates_scored
    assert low.peak_memory > 64.0  # the fallback does NOT fit, visibly
    assert any("pruned all" in r.message for r in caplog.records)
    # without a budget nothing is pruned and the flag stays down
    low2 = costed_lowering.lower_costed(
        w.plan, w.catalog, profile=cost.DeviceProfile.detect())
    assert not low2.budget_pruned_all and low2.budget_pruned == 0


def test_profile_budget_is_the_default_budget():
    """lower_costed inherits the profile's memory_budget (the serving
    path's channel) when no explicit budget is passed."""
    profile = dataclasses.replace(cost.DeviceProfile.detect(),
                                  memory_budget=64.0)
    w = workloads.simple_q1(scale=SCALE)
    low = costed_lowering.lower_costed(w.plan, w.catalog, profile=profile)
    assert low.memory_budget == 64.0 and low.budget_pruned_all


# ---------------------------------------------------------------------------
# multi-device: cache entry, serving routing, and a skew property test
# (run under the CI 8-fake-device step; skipped on a 1-device host)
# ---------------------------------------------------------------------------

multi_device = pytest.mark.skipif(
    len(jax.devices()) < 2, reason="needs >= 2 devices "
    "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")


def _budget_for(w, ways):
    profile = cost.DeviceProfile.detect()
    g = stage_graph.build(w.plan, w.catalog, profile=profile, ways=ways)
    peak_rep = cost.phys_peak_memory(g.realize(g.default_decisions()),
                                     w.catalog, profile)
    peak_part = cost.phys_peak_memory(g.realize(g.partitioned_decisions()),
                                      w.catalog, profile)
    assert peak_part < peak_rep
    return (peak_part + peak_rep) / 2.0


@multi_device
def test_partitioned_cache_entry_is_first_class():
    w = workloads.retail_q3(scale=SCALE)
    mesh = mesh_util.data_mesh()
    cache = PlanCache()
    cache.profile.memory_budget = _budget_for(w, mesh_util.batch_ways(mesh))
    key = cache.key(w.plan, w.catalog, mesh=mesh)
    assert "#be=part" in key and "#mesh=" in key
    assert any(t.startswith("pt") for t in key.split("#cl=")[1].split(";"))
    fn = cache.get_or_compile_partitioned(w.plan, w.catalog, mesh)
    assert cache._cache.get(key) is fn  # the key IS the entry's key
    plain = cache.get_or_compile(w.plan, w.catalog)
    assert plain is not fn
    out = fn(dict(w.catalog.tables))
    ref = plain(dict(w.catalog.tables))
    np.testing.assert_array_equal(np.asarray(ref.valid),
                                  np.asarray(out.valid))
    m = np.asarray(ref.valid)
    for c in ref.columns:
        np.testing.assert_allclose(np.asarray(ref[c])[m],
                                   np.asarray(out[c])[m],
                                   rtol=2e-5, atol=2e-5, err_msg=c)
    # warm call: same executable, no re-trace
    t0 = cache.traces
    assert cache.get_or_compile_partitioned(w.plan, w.catalog, mesh) is fn
    assert cache.traces == t0


@multi_device
def test_partitioned_composes_with_backend_override():
    """A node-level kernel override constrains the partitioned lowering
    (and its key) instead of being silently discarded: partitioning is a
    distribution choice, orthogonal to the caller's kernel choice."""
    w = workloads.retail_q3(scale=SCALE)
    mesh = mesh_util.data_mesh()
    cache = PlanCache()
    cache.profile.memory_budget = _budget_for(w, mesh_util.batch_ways(mesh))
    fn = cache.get_or_compile_partitioned(w.plan, w.catalog, mesh,
                                          backend="jnp")
    fn_plain = cache.get_or_compile_partitioned(w.plan, w.catalog, mesh)
    assert any("#be=part" in k and "#nbe=jnp" in k
               for k in cache._cache._data)
    key = cache.key(w.plan, w.catalog, mesh=mesh, backend="jnp")
    assert cache._cache.get(key) is fn
    m = np.asarray
    a, b = fn(dict(w.catalog.tables)), fn_plain(dict(w.catalog.tables))
    np.testing.assert_array_equal(m(a.valid), m(b.valid))


@multi_device
def test_partitioned_single_device_mesh_falls_back():
    w = workloads.simple_q1(scale=SCALE)
    cache = PlanCache()
    fb = cache.get_or_compile_partitioned(w.plan, w.catalog,
                                          mesh_util.data_mesh(1))
    assert fb is cache.get_or_compile(w.plan, w.catalog)


@multi_device
def test_server_routes_oversized_query_to_partitioned_path():
    w = workloads.retail_q3(scale=SCALE)
    mesh = mesh_util.data_mesh()
    budget = _budget_for(w, mesh_util.batch_ways(mesh))
    srv = __import__("repro.serving", fromlist=["QueryServer"]).QueryServer(
        max_batch_size=4, max_wait_s=3600.0, mesh=mesh,
        memory_budget=budget)
    req = srv.submit(w.plan, w.catalog)
    assert req.partitioned and "#be=part" in req.key
    # a query that fits stays on the plain path, same server
    small = workloads.simple_q1(scale=0.1)
    r2 = srv.submit(small.plan, small.catalog)
    assert not r2.partitioned and "#be=part" not in r2.key
    assert srv.drain() == 2
    assert req.error is None and r2.error is None
    st = srv.stats()
    assert st["partitioned_dispatches"] == 1
    sig = srv.signatures[req.key]
    assert sig.partitioned_dispatches == 1
    assert sig.ways == mesh_util.batch_ways(mesh)
    # the feedback export carries the multi-device calibration features
    from repro.serving import feedback
    e = [x for x in feedback.export_signature_stats(srv)
         if x.key == req.key][0]
    assert e.partitioned_dispatches == 1 and e.ways == sig.ways


# -- skew property test ------------------------------------------------------

LCAP, RCAP = 24, 40


@functools.lru_cache(maxsize=None)
def _join_runners(ways):
    """Jitted hash- and row-partitioned PJoin programs over fixed-capacity
    tables (one compile each; hypothesis examples vary only the contents)."""
    mesh = mesh_util.data_mesh()
    blk = mesh_util.row_block(LCAP, ways)
    roots = {
        "hash": ph.PRepartition(
            ph.PJoin(
                left=ph.PRepartition(ph.PScan("L"), op="bucket", ways=ways,
                                     in_capacity=LCAP, out_capacity=LCAP,
                                     key="k"),
                right=ph.PRepartition(ph.PScan("R"), op="bucket", ways=ways,
                                      in_capacity=RCAP, out_capacity=RCAP,
                                      key="rk"),
                left_key="k", right_key="rk", rprefix="r_"),
            op="combine", ways=ways, in_capacity=LCAP, out_capacity=LCAP),
        "row": ph.PRepartition(
            ph.PJoin(
                left=ph.PRepartition(ph.PScan("L"), op="slice", ways=ways,
                                     in_capacity=LCAP, out_capacity=blk),
                right=ph.PScan("R"), left_key="k", right_key="rk",
                rprefix="r_"),
            op="allgather", ways=ways, in_capacity=blk, out_capacity=LCAP),
    }
    out = {}
    for flavor, root in roots.items():
        pplan = ph.PhysicalPlan(root=root, registry=None, ways=ways)
        out[flavor] = jax.jit(mesh_util.shard_replicated(
            lambda t, p=pplan: ph.run(p, t, axis=mesh_util.DATA_AXIS), mesh))
    return out


@multi_device
def test_partitioned_join_property_on_skewed_keys():
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    ways = mesh_util.batch_ways(mesh_util.data_mesh())
    runners = _join_runners(ways)

    @settings(max_examples=12, deadline=None)
    @given(keys=st.lists(
               st.one_of(st.integers(0, RCAP - 1),
                         st.just(5)),  # skew mass on one bucket
               min_size=LCAP, max_size=LCAP),
           lvalid=st.lists(st.booleans(), min_size=LCAP, max_size=LCAP),
           rvalid=st.lists(st.booleans(), min_size=RCAP, max_size=RCAP))
    def check(keys, lvalid, rvalid):
        lt = Table.from_columns(
            {"k": jnp.asarray(keys, jnp.int32),
             "v": jnp.arange(LCAP, dtype=jnp.float32)},
            valid=jnp.asarray(lvalid))
        rt = Table.from_columns(
            {"rk": jnp.arange(RCAP, dtype=jnp.int32),
             "w": jnp.arange(RCAP, dtype=jnp.float32) * 0.5},
            valid=jnp.asarray(rvalid))
        ref = ops.fk_join(lt, rt, "k", "rk", "r_")
        for flavor, run in runners.items():
            out = run({"L": lt, "R": rt})
            np.testing.assert_array_equal(np.asarray(ref.valid),
                                          np.asarray(out.valid),
                                          err_msg=f"{flavor}.valid")
            m = np.asarray(ref.valid)
            for c in ref.columns:
                np.testing.assert_allclose(
                    np.asarray(ref[c])[m], np.asarray(out[c])[m],
                    rtol=2e-5, atol=2e-5, err_msg=f"{flavor}.{c}")

    check()


# ---------------------------------------------------------------------------
# the full multi-device proof, in a fresh 8-device process
# ---------------------------------------------------------------------------

def _forced_device_env(n: int = 8):
    env = dict(os.environ)
    flags = [t for t in env.get("XLA_FLAGS", "").split()
             if "--xla_force_host_platform_device_count" not in t]
    flags.append(f"--xla_force_host_platform_device_count={n}")
    env["XLA_FLAGS"] = " ".join(flags)
    env["JAX_PLATFORMS"] = "cpu"
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = os.path.abspath(src) + os.pathsep + env.get(
        "PYTHONPATH", "")
    return env


def test_partitioned_equals_reference_all_workloads_8dev():
    """Spawns ``tests/partitioned_equality_driver.py`` under a forced
    8-device host platform: row- and hash-partitioned realizations of all
    12 workloads equal the reference (masks/ints exact, floats 2e-5),
    skewed joins stay exact, and the memory-budget serving path works end
    to end."""
    driver = os.path.join(os.path.dirname(__file__),
                          "partitioned_equality_driver.py")
    proc = subprocess.run([sys.executable, driver], env=_forced_device_env(),
                          capture_output=True, text=True, timeout=1500)
    assert proc.returncode == 0, (
        f"driver failed\nstdout:\n{proc.stdout}\nstderr:\n{proc.stderr}")
    assert "all 12 workloads" in proc.stdout
    assert "budgeted serving: OK" in proc.stdout
