"""Batched-dispatch equivalence: stacking N parameterized instances of a
query and running the vmapped cached executable must produce the same
results as N sequential ``PlanCache`` dispatches — for every one of the 12
workload templates, and for the non-default 'relational' realizations."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ir
from repro.core.plan_cache import (PlanCache, stack_tables, unstack_table)
from repro.data import workloads
from repro.mlfuncs import builders
from repro.mlfuncs.functions import Atom, MLGraph, MLNode, MLFunction
from repro.mlfuncs.registry import Registry
from repro.relational.table import Table

SCALE = 0.25
BATCH = 3


def _assert_batched_equals_sequential(plan, catalog, batch=BATCH,
                                      rtol=2e-5, atol=2e-5):
    cache = PlanCache()
    tabs = workloads.rolled_instances(dict(catalog.tables), batch)
    run = cache.get_or_compile(plan, catalog)
    seq = [run(t) for t in tabs]
    run_b = cache.get_or_compile_batched(plan, catalog, batch)
    outs = run_b(tuple(tabs))
    assert len(outs) == batch
    for i, s in enumerate(seq):
        o = outs[i]
        assert set(o.columns) == set(s.columns)
        np.testing.assert_array_equal(np.asarray(o.valid), np.asarray(s.valid))
        for k in s.columns:
            np.testing.assert_allclose(np.asarray(o[k]), np.asarray(s[k]),
                                       rtol=rtol, atol=atol, err_msg=k)
    # exactly two traces: the sequential executable and the vmapped one
    assert cache.traces == 2
    return cache


@pytest.mark.parametrize("name", sorted(workloads.ALL_WORKLOADS))
def test_batched_equals_sequential_all_workloads(name):
    w = workloads.ALL_WORKLOADS[name](scale=SCALE)
    _assert_batched_equals_sequential(w.plan, w.catalog)


def test_batched_relational_realizations():
    """The literal tile/tree-relation pipelines (mode='relational') stream
    Table cross-joins inside lax.scan — they must vmap like everything
    else (static capacities, mask-aware)."""
    rng = np.random.default_rng(0)
    n = 16
    t = Table.from_columns({
        "id": jnp.arange(n, dtype=jnp.int32),
        "f": jnp.asarray(rng.standard_normal((n, 24)), jnp.float32)})
    cat = ir.Catalog()
    cat.add("t", t)
    reg = Registry()
    w = (rng.standard_normal((24, 48)) / 5).astype(np.float32)
    reg.register(MLFunction("mm", graph=MLGraph(
        [MLNode(0, Atom("matmul", {"w": w}), (("in", 0),))], 0, 1)))
    reg.register(builders.decision_forest("df", n_trees=8, depth=4,
                                          n_features=24, seed=2))
    bm = ir.BlockedMatmul(ir.Scan("t"), x_col="f", out_col="y", fn="mm")
    fr = ir.ForestRelational(bm, x_col="f", out_col="vote", fn="df",
                             keep=("id", "y"))
    plan = ir.Plan(fr, reg, phys={
        bm.uid: ir.PhysConfig(mode="relational", backend="jnp", n_tiles=3),
        fr.uid: ir.PhysConfig(mode="relational", backend="jnp")})
    _assert_batched_equals_sequential(plan, cat, rtol=1e-5, atol=1e-5)


def test_batched_executable_is_cached_per_batch_size():
    w = workloads.ALL_WORKLOADS["simple_q1"](scale=SCALE)
    cache = PlanCache()
    f2 = cache.get_or_compile_batched(w.plan, w.catalog, 2)
    f2b = cache.get_or_compile_batched(w.plan, w.catalog, 2)
    assert f2b is f2 and cache.stats.hits == 1
    f3 = cache.get_or_compile_batched(w.plan, w.catalog, 3)
    assert f3 is not f2 and cache.stats.misses == 2
    # batched and unbatched variants key separately
    f1 = cache.get_or_compile(w.plan, w.catalog)
    assert f1 is not f2 and cache.stats.misses == 3


def test_batched_executable_rejects_wrong_batch_size():
    w = workloads.ALL_WORKLOADS["simple_q1"](scale=SCALE)
    cache = PlanCache()
    tabs = workloads.rolled_instances(dict(w.catalog.tables), 3)
    run_b = cache.get_or_compile_batched(w.plan, w.catalog, 3)
    with pytest.raises(ValueError, match="batch_size"):
        run_b(tuple(tabs[:2]))
    with pytest.raises(ValueError):
        cache.get_or_compile_batched(w.plan, w.catalog, 0)


def test_full_and_restricted_table_dicts_share_one_trace():
    """simple_q1 scans one of the seven tpcxai tables; callers passing the
    full catalog dict and callers passing only the scanned tables must hit
    the same traced structure (no silent recompile on the warm path)."""
    from repro.core.plan_cache import scan_table_names
    w = workloads.ALL_WORKLOADS["simple_q1"](scale=SCALE)
    names = scan_table_names(w.plan)
    assert len(names) < len(w.catalog.tables)
    cache = PlanCache()
    fn = cache.get_or_compile(w.plan, w.catalog)
    fn(dict(w.catalog.tables))                       # full catalog payload
    fn({k: w.catalog.tables[k] for k in names})      # restricted payload
    assert cache.traces == 1


def test_stack_unstack_roundtrip():
    w = workloads.ALL_WORKLOADS["simple_q1"](scale=SCALE)
    tabs = workloads.rolled_instances(dict(w.catalog.tables), 2)
    stacked = stack_tables(tabs)
    for name, table in stacked.items():
        assert table.valid.shape[0] == 2
        for col in table.columns.values():
            assert col.shape[0] == 2
    for i, orig in enumerate(tabs):
        back = {k: unstack_table(v, i) for k, v in stacked.items()}
        for k in orig:
            np.testing.assert_array_equal(np.asarray(back[k].valid),
                                          np.asarray(orig[k].valid))
    with pytest.raises(ValueError):
        stack_tables([])
