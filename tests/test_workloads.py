"""All 12 paper workloads execute; optimization preserves results end-to-end."""
import numpy as np
import pytest

from repro.core.executor import execute
from repro.core.planner import STRATEGIES, analytic_cost_fn
from repro.data import workloads


@pytest.mark.parametrize("name", sorted(workloads.ALL_WORKLOADS))
def test_workload_executes(name):
    w = workloads.ALL_WORKLOADS[name](scale=0.3)
    out = execute(w.plan, w.catalog)
    assert int(out.num_valid()) > 0
    arrs = out.to_numpy()
    for k, v in arrs.items():
        assert np.isfinite(np.asarray(v, np.float64)).all(), k


@pytest.mark.parametrize("name", ["rec_q1", "rec_q2", "retail_q1",
                                  "retail_q2", "analytics_q1"])
def test_optimized_workload_equivalent(name):
    w = workloads.ALL_WORKLOADS[name](scale=0.3)
    cost_fn = analytic_cost_fn(w.catalog, memory_budget=w.memory_budget)
    base = execute(w.plan, w.catalog).canonical()
    p2, stats = STRATEGIES["vanilla_mcts"](w.plan, w.catalog, cost_fn=cost_fn,
                                           iterations=15, seed=0)
    out = execute(p2, w.catalog).canonical()
    assert set(base) == set(out)
    for k in base:
        np.testing.assert_allclose(base[k], out[k], rtol=5e-4, atol=5e-4,
                                   err_msg=f"{name}:{k}")


def test_templates_all_execute():
    from repro.data import templates
    for t in range(1, 21):
        plan, cat = templates.sample_query(t, seed=50 + t, scale=0.3)
        out = execute(plan, cat)
        assert int(out.num_valid()) >= 0, f"template {t}"


def test_ood_split():
    from repro.data.templates import ood_split
    ind, ood = ood_split()
    assert len(ind) == 14 and len(ood) == 6
    assert not set(ind) & set(ood)
