"""Model2Vec / Query2Vec / WL kernel / latency head."""
import numpy as np
import pytest

from repro.core import optimizer as om
from repro.core import wl
from repro.core.planner import analytic_cost_fn
from repro.data import templates
from repro.mlfuncs import builders


def test_wl_kernel_properties():
    g1 = builders.ffnn("a", [16, 32, 1], seed=0).graph
    g2 = builders.ffnn("b", [16, 32, 1], seed=1).graph   # same structure
    g3 = builders.decision_forest("c", 8, 4, 16, seed=2).graph
    f1, f2, f3 = wl.graph_wl(g1), wl.graph_wl(g2), wl.graph_wl(g3)
    assert wl.wl_similarity(f1, f1) == pytest.approx(1.0)
    assert wl.wl_similarity(f1, f2) > wl.wl_similarity(f1, f3)


def test_plan_wl_rewrite_invariance():
    """Rule-generated fn-name suffixes must not change WL labels (so states
    of rewritten plans from different queries can still collide)."""
    plan, cat = templates.sample_query(1, seed=3, scale=0.3)
    f1 = wl.plan_wl(plan.root, plan.registry)
    from repro.core.rules import ALL_RULES
    cfgs = ALL_RULES["R4-1-fuse"].configs(plan, cat)
    if cfgs:
        p2 = ALL_RULES["R4-1-fuse"].apply(plan, cat, cfgs[0])
        f2 = wl.plan_wl(p2.root, p2.registry)
        assert wl.wl_similarity(f1, f2) > 0.5


def test_embedding_shapes_and_determinism():
    emb = om.init_embedder(0)
    plan, cat = templates.sample_query(2, seed=1, scale=0.3)
    e1 = emb.embed(plan, cat)
    e2 = emb.embed(plan, cat)
    assert e1.shape == (393,)  # paper Sec. IV-B2 dimensionality
    np.testing.assert_allclose(e1, e2)
    assert abs(np.linalg.norm(e1) - 1.0) < 1e-4


def test_contrastive_training_separates():
    emb = om.init_embedder(0)
    graphs = [builders.sample_model(s).graph for s in range(16)]
    graphs = [g for g in graphs if g is not None]
    r = om.train_model2vec(emb, graphs, steps=40, batch=8, lr=1e-4)
    assert np.isfinite(r["loss_last"])


def test_latency_head_learns_ranking():
    emb = om.init_embedder(1)
    plans, cats, costs = [], [], []
    for t in (1, 5, 7, 11, 15, 16, 17, 18):
        for s in range(3):
            p, c = templates.sample_query(t, seed=100 * t + s, scale=0.3)
            plans.append(p)
            cats.append(c)
            costs.append(analytic_cost_fn(c)(p))
    om.train_query2vec(emb, plans, cats, steps=40, batch=8)
    om.train_latency(emb, plans, cats, costs, steps=150, batch=8)
    pred = np.array([emb.predict_latency(p, c) for p, c in zip(plans, cats)])
    corr = np.corrcoef(np.log(pred + 1e-12), np.log(np.array(costs)))[0, 1]
    assert corr > 0.5, f"latency head failed to learn ranking (corr={corr})"


def test_two_model_vs_one_model_strategy():
    emb = om.init_embedder(2)
    plans, cats, costs = [], [], []
    for t in (1, 7, 16):
        for s in range(2):
            p, c = templates.sample_query(t, seed=10 * t + s, scale=0.3)
            plans.append(p)
            cats.append(c)
            costs.append(analytic_cost_fn(c)(p))
    r2 = om.train_latency(emb, plans, cats, costs, steps=50, one_model=False)
    assert not emb.one_model
    emb1 = om.init_embedder(3)
    r1 = om.train_latency(emb1, plans, cats, costs, steps=50, one_model=True)
    assert emb1.one_model
    assert np.isfinite(r1["loss_last"]) and np.isfinite(r2["loss_last"])
