"""Costed lowering: equality with the reference on all 12 workloads,
strictly cheaper plans where the oracle finds them, one shared plan_cost
entry point across MCTS and lower(), and calibration-driven re-lowering
without stale-executable aliasing in the PlanCache."""
import dataclasses

import numpy as np
import pytest

from repro.core import cost, costed_lowering, executor, ir, stage_graph
from repro.core import physical as ph
from repro.core.lowering import lower
from repro.core.mcts import VanillaMCTS
from repro.core.plan_cache import PlanCache
from repro.data import workloads
from repro.serving import feedback

SCALE = 0.5


def assert_tables_equal(ref, out, label):
    """Masks/integer columns exact; floats to the established 2e-5 vmap
    tolerance (canonicalized: valid rows only, order-independent)."""
    assert set(ref) == set(out), f"{label}: schema {sorted(set(ref) ^ set(out))}"
    for k in ref:
        a, b = ref[k], out[k]
        assert a.shape == b.shape, f"{label}:{k} {a.shape} vs {b.shape}"
        if np.issubdtype(a.dtype, np.integer) or a.dtype == bool:
            np.testing.assert_array_equal(a, b, err_msg=f"{label}:{k}")
        else:
            np.testing.assert_allclose(a, b, rtol=2e-5, atol=2e-5,
                                       err_msg=f"{label}:{k}")


# ---------------------------------------------------------------------------
# equality + strictly-cheaper (acceptance criteria)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(workloads.ALL_WORKLOADS))
def test_costed_lowering_equals_reference(name):
    w = workloads.ALL_WORKLOADS[name](scale=SCALE)
    ref = executor.execute_reference(w.plan, w.catalog).canonical()
    out = ph.run(lower(w.plan, w.catalog), dict(w.catalog.tables)).canonical()
    assert_tables_equal(ref, out, name)


def test_costed_lowering_strictly_cheaper_on_some_workloads():
    """The oracle must find a strictly cheaper realization than tree-order
    lowering on at least 3 of the 12 workloads (compaction insertion after
    the selective ML filters is the main win at this scale)."""
    profile = cost.DeviceProfile.detect()
    cheaper = []
    for name in sorted(workloads.ALL_WORKLOADS):
        w = workloads.ALL_WORKLOADS[name](scale=SCALE)
        c_tree = cost.plan_cost(lower(w.plan, w.catalog, costed=False),
                                w.catalog, profile)
        c_best = cost.plan_cost(lower(w.plan, w.catalog, profile=profile),
                                w.catalog, profile)
        assert c_best <= c_tree * (1 + 1e-12), name  # never worse
        if c_best < c_tree * (1 - 1e-9):
            cheaper.append(name)
    assert len(cheaper) >= 3, cheaper


def test_default_decisions_reproduce_tree_order_lowering():
    """realize(default_decisions) must be the exact tree-order physical
    plan: same signature, same analytic cost (the candidate baseline)."""
    for name in ("rec_q1", "analytics_q1", "simple_q3"):
        w = workloads.ALL_WORKLOADS[name](scale=0.3)
        g = stage_graph.build(w.plan, w.catalog,
                              profile=cost.DeviceProfile.detect())
        tree = lower(w.plan, w.catalog, costed=False)
        assert g.realize(g.default_decisions()).signature() == tree.signature()


def test_backend_override_wins_over_cost_choice():
    """A caller's backend override restricts every realization candidate —
    the caller's kernel choice is sovereign over the oracle's."""
    from repro.core.rules import ALL_RULES

    w = workloads.analytics_q1(scale=0.3)
    cfgs = ALL_RULES["R3-2"].configs(w.plan, w.catalog)
    assert cfgs, "R3-2 must apply to the forest workload"
    plan = ALL_RULES["R3-2"].apply(w.plan, w.catalog, cfgs[0])
    for be in ("jnp", "sharded"):  # plan-level 'sharded' resolves to jnp
        pplan = lower(plan, w.catalog, backend=be)
        seen = 0
        for node in _walk_phys(pplan.root):
            if isinstance(node, (ph.PBlockedMatmul, ph.PForestRelational)):
                assert node.backend == "jnp"
                seen += 1
        assert seen >= 1


def _walk_phys(node):
    yield node
    for c in node.children():
        yield from _walk_phys(c)


# ---------------------------------------------------------------------------
# one shared plan_cost entry point (MCTS + lower)
# ---------------------------------------------------------------------------

def test_mcts_and_lowering_share_the_plan_cost_oracle(monkeypatch):
    calls = {"n": 0}
    real = cost.plan_cost

    def counting(*a, **kw):
        calls["n"] += 1
        return real(*a, **kw)

    monkeypatch.setattr(cost, "plan_cost", counting)
    w = workloads.rec_q1(scale=0.3)  # has open sites: >1 candidate scored
    # costed lowering scores its candidates through cost.plan_cost
    costed_lowering.lower_costed(w.plan, w.catalog)
    lowering_calls = calls["n"]
    assert lowering_calls > 1
    # the MCTS default reward oracle is the same entry point
    m = VanillaMCTS(w.catalog, iterations=2, seed=0)
    m.optimize(w.plan)
    assert calls["n"] > lowering_calls


def test_plan_cost_accepts_both_plan_levels():
    """Logical and (tree-order) physical costing agree bit-for-bit: one set
    of per-operator kernels behind one entry point."""
    profile = cost.DeviceProfile.detect()
    for name in sorted(workloads.ALL_WORKLOADS):
        w = workloads.ALL_WORKLOADS[name](scale=0.3)
        c_log = cost.plan_cost(w.plan, w.catalog, profile)
        c_phys = cost.plan_cost(lower(w.plan, w.catalog, costed=False),
                                w.catalog, profile)
        assert c_phys == pytest.approx(c_log, rel=1e-12), name


# ---------------------------------------------------------------------------
# decision vector in PlanCache keys + calibration-driven re-lowering
# ---------------------------------------------------------------------------

def test_plan_cache_key_reflects_realization_vector():
    w = workloads.rec_q2(scale=SCALE)
    cache = PlanCache()
    key = cache.key(w.plan, w.catalog)
    assert "#cl=" in key
    low = costed_lowering.lower_costed(w.plan, w.catalog,
                                       profile=cache.profile)
    assert key.endswith("#cl=" + low.signature)


def _true_device_exports(prior):
    """Measurements a dispatch-overhead-heavy, high-bandwidth device would
    produce (deterministic: linearized predictions of a synthetic profile)."""
    true = dataclasses.replace(prior, op_overhead_s=5e-4, hbm_bw=6e11,
                               peak_flops=2e13)
    exports = []
    for name in ("rec_q2", "simple_q1"):
        w = workloads.ALL_WORKLOADS[name](scale=SCALE)
        b = cost.plan_cost_breakdown(w.plan, w.catalog, prior)
        t = (b.flops / true.peak_flops
             + (b.hbm_bytes + b.param_bytes) / true.hbm_bw
             + b.n_ops * true.op_overhead_s)
        exports.append(feedback.SignatureExport(
            key=name, requests=20, dispatches=20, mean_occupancy=1.0,
            mean_dispatch_s=t, mean_wait_s=0.0, plan=w.plan,
            catalog=w.catalog))
    return exports


def test_calibrated_profile_changes_lowering_decision_without_aliasing():
    """Acceptance: feedback-calibrated profiles change a lowering decision
    in a fixed-seed test, and the PlanCache selects a different executable
    under a new key instead of aliasing the stale one."""
    w = workloads.rec_q2(scale=SCALE)
    cache = PlanCache()
    k0 = cache.key(w.plan, w.catalog)
    fn0 = cache.get_or_compile(w.plan, w.catalog)
    assert cache._cache.get(k0) is fn0

    fit = feedback.apply_calibration(cache, _true_device_exports(cache.profile))
    assert fit.n_samples == 2
    assert cache.profile_epoch == 1
    # per-op overhead rose by orders of magnitude: the marginal compaction
    # no longer pays, so the decision vector (and the key) change
    k1 = cache.key(w.plan, w.catalog)
    fn1 = cache.get_or_compile(w.plan, w.catalog)
    assert k1 != k0, "calibration did not change the lowering decision"
    assert fn1 is not fn0, "stale executable aliased after recalibration"
    # the old entry is still the old executable under the old key (LRU
    # retires it eventually); the new key maps to the new one
    assert cache._cache.get(k0) is fn0
    assert cache._cache.get(k1) is fn1
    # results agree: realizations only differ in predicted latency
    out0 = fn0(dict(w.catalog.tables)).canonical()
    out1 = fn1(dict(w.catalog.tables)).canonical()
    assert_tables_equal(out0, out1, "recalibrated")


def test_stale_submit_memo_key_is_refreshed_after_recalibration():
    """The serving tier memoizes keys at admission; a recalibrated profile
    must invalidate the memo (epoch check), not dispatch stale keys."""
    from repro.serving.server import QueryServer

    w = workloads.rec_q2(scale=SCALE)
    server = QueryServer(max_batch_size=1, max_wait_s=0.0)
    r0 = server.submit(w.plan, w.catalog)
    server.drain()
    feedback.apply_calibration(server.cache,
                               _true_device_exports(server.cache.profile))
    r1 = server.submit(w.plan, w.catalog)
    server.drain()
    assert r0.error is None and r1.error is None
    assert r1.key != r0.key, "submit memo served a stale pre-calibration key"


# ---------------------------------------------------------------------------
# vmapped-vs-sharded batch realization through the oracle
# ---------------------------------------------------------------------------

def test_choose_batch_realization_costed():
    jax_mesh = pytest.importorskip("jax.sharding")
    import jax
    from repro.core import mesh as mesh_util

    w = workloads.simple_q1(scale=0.3)
    if len(jax.devices()) > 1:
        mesh = mesh_util.data_mesh()
        ways = mesh_util.batch_ways(mesh)
        b = 2 * ways
        # default collective priors are small (non-zero, so collectives are
        # never free): sharding an eligible batch is still predicted to pay
        assert costed_lowering.choose_batch_realization(
            w.plan, w.catalog, b, mesh) == "sharded"
        # a profile whose per-shard collective overhead dwarfs the work
        # flips the choice to the single-device vmapped program
        slow = dataclasses.replace(cost.DeviceProfile.detect(),
                                   collective_overhead_s=10.0)
        assert costed_lowering.choose_batch_realization(
            w.plan, w.catalog, b, mesh, profile=slow) == "batched"
    # ineligible is always batched
    assert costed_lowering.choose_batch_realization(
        w.plan, w.catalog, 4, None) == "batched"
