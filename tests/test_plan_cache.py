"""Compiled-plan cache + LRU machinery + embedder cache bounding."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import executor, ir
from repro.core.plan_cache import LRUCache, PlanCache, schema_signature
from repro.mlfuncs import builders
from repro.mlfuncs.registry import Registry
from repro.relational.table import Table


def _mini_setup(seed=0, n=32):
    """Fresh data per seed; the registered model is the same (the cache's
    contract: a registered fn name is a stable identity, same name ⇒ same
    weights, as in a model registry)."""
    rng = np.random.default_rng(seed)
    t = Table.from_columns({
        "id": jnp.arange(n, dtype=jnp.int32),
        "x": jnp.asarray(rng.uniform(0, 10, n), jnp.float32),
        "f": jnp.asarray(rng.standard_normal((n, 8)), jnp.float32)})
    cat = ir.Catalog()
    cat.add("t", t)
    reg = Registry()
    reg.register(builders.ffnn("m", [8, 16, 1], seed=1))
    root = ir.Project(
        ir.Filter(ir.Scan("t"), pred=ir.Cmp(">", ir.Col("x"), ir.Const(3.0))),
        outputs=(("score", ir.Call("m", (ir.Col("f"),))),),
        keep=("id",))
    return ir.Plan(root, reg), cat


def test_repeated_identical_query_hits_without_retrace():
    cache = PlanCache()
    plan1, cat1 = _mini_setup(seed=0)
    fn1 = cache.get_or_compile(plan1, cat1)
    out1 = fn1(dict(cat1.tables))
    jax.block_until_ready(out1)
    assert cache.stats.misses == 1 and cache.traces == 1

    # a structurally identical query built from scratch (fresh tree, fresh
    # registry, fresh — but same-shaped — data): hit, zero re-traces
    plan2, cat2 = _mini_setup(seed=7)
    fn2 = cache.get_or_compile(plan2, cat2)
    out2 = fn2(dict(cat2.tables))
    jax.block_until_ready(out2)
    assert cache.stats.hits == 1
    assert cache.traces == 1, "second structurally identical query re-traced"
    assert fn2 is fn1

    # and it computed the *fresh* data, not the cached plan's data
    ref2 = executor.execute(plan2, cat2)
    np.testing.assert_allclose(out2.canonical()["score"],
                               ref2.canonical()["score"], rtol=1e-5, atol=1e-6)


def test_different_structure_or_schema_misses():
    cache = PlanCache()
    plan, cat = _mini_setup()
    cache.get_or_compile(plan, cat)
    # different predicate constant -> different signature
    other = ir.Plan(ir.Filter(ir.Scan("t"),
                              pred=ir.Cmp(">", ir.Col("x"), ir.Const(5.0))),
                    plan.registry)
    cache.get_or_compile(other, cat)
    assert cache.stats.misses == 2
    # different capacity -> different schema signature
    _, cat2 = _mini_setup(n=64)
    assert schema_signature(cat) != schema_signature(cat2)
    cache.get_or_compile(plan, cat2)
    assert cache.stats.misses == 3
    # same fn name, different architecture -> different registry signature
    reg2 = Registry()
    reg2.register(builders.ffnn("m", [8, 32, 1], seed=1))  # wider hidden
    plan_arch = ir.Plan(plan.root, reg2)
    cache.get_or_compile(plan_arch, cat)
    assert cache.stats.misses == 4


def test_unscanned_catalog_table_does_not_over_key():
    """Regression: ``PlanCache.key`` used to hash the schema of *every*
    catalog table, so adding an unrelated table false-missed the cache and
    retraced. The key is restricted to the plan's scanned tables: the same
    plan over catalog +- an unscanned table is one entry, one trace."""
    cache = PlanCache()
    plan, cat = _mini_setup(seed=0)
    fn1 = cache.get_or_compile(plan, cat)
    jax.block_until_ready(fn1(dict(cat.tables)))
    assert cache.stats.misses == 1 and cache.traces == 1

    # same plan, catalog with an extra table the plan never scans
    plan2, cat2 = _mini_setup(seed=3)
    cat2.add("unrelated", Table.from_columns(
        {"k": jnp.arange(5, dtype=jnp.int32)}))
    assert schema_signature(cat) != schema_signature(cat2)  # full-catalog view
    assert cache.key(plan, cat) == cache.key(plan2, cat2)   # restricted key
    fn2 = cache.get_or_compile(plan2, cat2)
    jax.block_until_ready(fn2(dict(cat2.tables)))
    assert fn2 is fn1, "unscanned table false-missed the cache"
    assert cache.stats.hits == 1 and cache.stats.misses == 1
    assert cache.traces == 1, "unscanned table forced a retrace"
    assert len(cache._cache) == 1

    # removing the unrelated table again is still the same entry
    fn3 = cache.get_or_compile(plan2, cat)
    assert fn3 is fn1 and cache.stats.hits == 2

    # but a *scanned* table's shape still keys: capacity change must miss
    _, cat_big = _mini_setup(n=64)
    cache.get_or_compile(plan, cat_big)
    assert cache.stats.misses == 2


def test_compile_plan_goes_through_cache():
    plan, cat = _mini_setup()
    cache = PlanCache()
    run = executor.compile_plan(plan, cat, cache=cache)
    a = run().canonical()
    run2 = executor.compile_plan(plan, cat, cache=cache)
    b = run2().canonical()
    assert cache.stats.hits == 1 and cache.traces == 1
    np.testing.assert_allclose(a["score"], b["score"])


def test_lru_cache_bounds_and_stats():
    c = LRUCache(maxsize=2)
    c.put("a", 1)
    c.put("b", 2)
    assert c.get("a") == 1          # refresh a
    c.put("c", 3)                   # evicts b (LRU)
    assert c.stats.evictions == 1
    assert "b" not in c and "a" in c and "c" in c
    assert c.get("b") is None
    assert c.stats.hits == 1 and c.stats.misses == 1
    assert len(c) == 2


def test_lru_cache_eviction_order_under_interleaved_get_put():
    c = LRUCache(maxsize=3)
    c.put("a", 1)
    c.put("b", 2)
    c.put("c", 3)
    assert c.get("a") == 1          # order now b, c, a
    c.put("b", 20)                  # refresh by put: order c, a, b
    c.put("d", 4)                   # evicts c (true LRU, not insert order)
    assert "c" not in c and "a" in c and "b" in c and "d" in c
    assert c.get("c") is None
    c.put("e", 5)                   # evicts a (oldest touch)
    assert "a" not in c and "b" in c and "d" in c and "e" in c
    assert c.get("b") == 20         # refreshed value survived
    assert c.stats.evictions == 2


def test_lru_cache_clear_resets_contents_but_preserves_stats():
    c = LRUCache(maxsize=4)
    c.put("a", 1)
    assert c.get("a") == 1 and c.get("zz") is None
    hits, misses = c.stats.hits, c.stats.misses
    c.clear()
    assert len(c) == 0 and "a" not in c
    # stats survive a clear: the counters describe lifetime traffic
    assert c.stats.hits == hits and c.stats.misses == misses
    assert c.get("a") is None       # post-clear lookup is a miss
    assert c.stats.misses == misses + 1
    c.put("b", 2)                   # cache is usable again
    assert c.get("b") == 2


def test_lru_cache_maxsize_one_edge_case():
    c = LRUCache(maxsize=1)
    c.put("a", 1)
    c.put("b", 2)                   # immediately evicts a
    assert len(c) == 1 and "a" not in c and c.get("b") == 2
    assert c.stats.evictions == 1
    c.put("b", 3)                   # overwrite in place: no eviction
    assert c.get("b") == 3 and c.stats.evictions == 1
    # maxsize is clamped to >= 1 so the cache can always hold one entry
    assert LRUCache(maxsize=0).maxsize == 1
    assert LRUCache(maxsize=-5).maxsize == 1


def test_query_embedder_cache_is_bounded_with_stats():
    om = pytest.importorskip("repro.core.optimizer")
    emb = om.init_embedder(0)
    plan, cat = _mini_setup()
    e1 = emb.embed(plan, cat)
    e2 = emb.embed(plan, cat)
    np.testing.assert_allclose(e1, e2)
    assert emb.cache_stats.hits == 1 and emb.cache_stats.misses == 1
    assert emb._cache.maxsize == om.EMBED_CACHE_SIZE
