"""Cost-oracle properties: profile detection, compaction-placement and
pallas-vs-jnp monotonicity (property-style over the 12 workloads), batched
/sharded scaling, and calibration fitting."""
import dataclasses

import numpy as np
import pytest

from repro.core import cost, ir, stage_graph
from repro.core.lowering import lower
from repro.data import workloads
from repro.mlfuncs import builders
from repro.mlfuncs.registry import Registry


# ---------------------------------------------------------------------------
# DeviceProfile.detect
# ---------------------------------------------------------------------------

def test_detect_maps_jax_backend(monkeypatch):
    import jax
    for backend, name, pallas in (("tpu", "tpu-v5e", True),
                                  ("gpu", "gpu-a100", False),
                                  ("cpu", "cpu", False)):
        monkeypatch.setattr(jax, "default_backend", lambda b=backend: b)
        p = cost.DeviceProfile.detect()
        assert p.name == name and p.supports_pallas == pallas
    # detect() returns fresh copies: calibrating one must not leak into the
    # module priors
    p = cost.DeviceProfile.detect()
    p.op_overhead_s = 123.0
    assert cost.CPU_PROFILE.op_overhead_s != 123.0
    assert cost.DeviceProfile.detect().op_overhead_s != 123.0


def test_profile_signature_tracks_calibratable_fields():
    a = cost.DeviceProfile.detect()
    b = dataclasses.replace(a, op_overhead_s=a.op_overhead_s * 2)
    assert a.signature() != b.signature()
    assert a.signature() == dataclasses.replace(a).signature()


# ---------------------------------------------------------------------------
# compaction placement monotonicity (property over the 12 workloads)
# ---------------------------------------------------------------------------

def _selective_filters_over_full_inputs(plan, catalog):
    """Filters whose *own* selectivity is the source of the shrink: the
    input's sound live-row bound fills its capacity, while the output's
    sound bound compacts strictly below it."""
    out = []
    for n in ir.walk(plan.root):
        if not isinstance(n, ir.Filter):
            continue
        b_after = stage_graph.sound_rows_bound(n, plan.registry, catalog)
        b_before = stage_graph.sound_rows_bound(n.child, plan.registry,
                                                catalog)
        if b_after is None or b_before is None:
            continue
        cap = ir.infer(n, plan.registry, catalog).capacity
        if (b_before >= cap * 0.95
                and stage_graph.compact_capacity(b_after) < cap):
            out.append((n, b_after))
    return out


def test_compact_after_selective_filter_cheaper_than_before():
    """Compaction *after* a selective filter must cost less than before it.

    Capacities are position-dependent correctness bounds: before the filter
    the soundest compact cannot shrink below the input's live rows (here:
    the full capacity — pure overhead), while after the filter it shrinks
    to the surviving rows and every downstream pass gets cheaper. This is
    exactly why the stage graph glues inserted compacts *behind* their
    filter. Property-style over every eligible workload."""
    profile = cost.DeviceProfile.detect()
    checked = 0
    for name in sorted(workloads.ALL_WORKLOADS):
        w = workloads.ALL_WORKLOADS[name](scale=0.5)
        for f, bound_after in _selective_filters_over_full_inputs(w.plan,
                                                                  w.catalog):
            cap_in = ir.infer(f, w.plan.registry, w.catalog).capacity
            cap_after = stage_graph.compact_capacity(bound_after)
            after_root = ir.replace_node(
                w.plan.root, f, ir.Compact(f, capacity=cap_after))
            before_root = ir.replace_node(
                w.plan.root, f, dataclasses.replace(
                    f, child=ir.Compact(f.child, capacity=cap_in)))
            c_after = cost.plan_cost(
                ir.Plan(after_root, w.plan.registry, w.plan.phys),
                w.catalog, profile)
            c_before = cost.plan_cost(
                ir.Plan(before_root, w.plan.registry, w.plan.phys),
                w.catalog, profile)
            assert c_after < c_before, (name, cap_after, cap_in)
            checked += 1
    assert checked >= 3, "too few workloads with a selective filter"


def test_costed_lowering_places_compact_after_the_selective_filter():
    """The stage graph only ever glues an inserted compact *after* its
    filter, and the chosen plan is never analytically worse than tree
    order (the oracle's pick is consistent with the monotonicity above)."""
    from repro.core import physical as ph

    w = workloads.rec_q1(scale=0.5)
    pplan = lower(w.plan, w.catalog)

    def pipelines(node):
        if isinstance(node, ph.PPipeline):
            yield node
        for c in node.children():
            yield from pipelines(c)

    inserted = 0
    for p in pipelines(pplan.root):
        kinds = [type(s).__name__ for s in p.stages]
        for i, k in enumerate(kinds):
            if k == "CompactStage":
                assert i > 0 and kinds[i - 1] == "FilterStage"
                inserted += 1
    assert inserted >= 1, "expected an inserted compact on rec_q1"


# ---------------------------------------------------------------------------
# pallas-vs-jnp consistency (property over the 12 workloads)
# ---------------------------------------------------------------------------

def _r3_annotated_plans(w, rule_name):
    from repro.core.rules import ALL_RULES
    rule = ALL_RULES[rule_name]
    cfgs = rule.configs(w.plan, w.catalog)
    if not cfgs:
        return None
    return rule.apply(w.plan, w.catalog, cfgs[0])


def test_pallas_costs_less_than_jnp_exactly_when_model_says_so():
    """For every workload where an R3 rule applies: the pallas realization
    of the annotated node costs less than jnp exactly when the analytic
    model's bandwidth term is binding (pallas reads through vmem_bw >
    hbm_bw; the compute term is backend-independent)."""
    profile = cost.TPU_PROFILE  # pallas-capable (analytic only, no exec)
    checked = 0
    for name in sorted(workloads.ALL_WORKLOADS):
        w = workloads.ALL_WORKLOADS[name](scale=0.5)
        plan = (_r3_annotated_plans(w, "R3-1")
                or _r3_annotated_plans(w, "R3-2"))
        if plan is None:
            continue
        uid, cfg = next(iter(plan.phys.items()))
        p_jnp = plan.with_phys(uid, dataclasses.replace(cfg, backend="jnp"))
        p_pal = plan.with_phys(uid, dataclasses.replace(cfg, backend="pallas"))
        c_jnp = cost.plan_cost(p_jnp, w.catalog, profile)
        c_pal = cost.plan_cost(p_pal, w.catalog, profile)
        # find the annotated node and ask the model which term binds
        node = next(n for n in ir.walk(plan.root)
                    if getattr(n, "uid", None) == uid)
        oc = cost._node_op_cost(node, plan.registry, w.catalog, profile,
                                p_jnp.phys)
        bw_bound = ((oc.data_bytes + oc.param_bytes) / profile.hbm_bw
                    > oc.flops / profile.peak_flops)
        if bw_bound:
            assert c_pal < c_jnp, name
        else:
            assert c_pal == pytest.approx(c_jnp, rel=1e-12), name
        checked += 1
    assert checked >= 3


# ---------------------------------------------------------------------------
# batched / sharded scaling
# ---------------------------------------------------------------------------

def test_batched_cost_scales_with_occupancy_and_shards():
    w = workloads.rec_q2(scale=0.3)
    prof = cost.CPU_PROFILE
    c1 = cost.batched_plan_cost(w.plan, w.catalog, 1, prof)
    c8 = cost.batched_plan_cost(w.plan, w.catalog, 8, prof)
    assert c8 > c1  # more queries, more work
    c8s = cost.batched_plan_cost(w.plan, w.catalog, 8, prof, ways=4)
    assert c8s < c8  # four shards each run the 2-query slice
    slow = dataclasses.replace(prof, collective_overhead_s=10.0)
    assert (cost.batched_plan_cost(w.plan, w.catalog, 8, slow, ways=4)
            > cost.batched_plan_cost(w.plan, w.catalog, 8, slow))


# ---------------------------------------------------------------------------
# calibration fit
# ---------------------------------------------------------------------------

def _samples(profile, names=("rec_q2", "simple_q1", "retail_q1"), scale=0.5,
             true=None):
    out = []
    for name in names:
        w = workloads.ALL_WORKLOADS[name](scale=scale)
        b = cost.plan_cost_breakdown(w.plan, w.catalog, profile)
        ref = true or profile
        t = (b.flops / ref.peak_flops
             + (b.hbm_bytes + b.param_bytes) / ref.hbm_bw
             + b.n_ops * ref.op_overhead_s)
        out.append((b, t, 1.0))
    return out


def test_fit_profile_recovers_prior_on_consistent_data():
    prior = cost.CPU_PROFILE
    fit = cost.fit_profile(_samples(prior), prior)
    assert fit.mape_after < 1e-6
    assert fit.profile.peak_flops == pytest.approx(prior.peak_flops, rel=0.05)
    assert fit.profile.op_overhead_s == pytest.approx(prior.op_overhead_s,
                                                      rel=0.05)


def test_fit_profile_moves_toward_true_device():
    prior = cost.CPU_PROFILE
    true = dataclasses.replace(prior, op_overhead_s=5e-4, hbm_bw=6e11,
                               peak_flops=2e13)
    fit = cost.fit_profile(_samples(prior, true=true), prior)
    assert fit.mape_after < fit.mape_before
    # direction (not exactness): every coefficient moved toward the truth
    assert fit.profile.op_overhead_s > prior.op_overhead_s * 10
    assert fit.profile.hbm_bw > prior.hbm_bw
    assert fit.profile.peak_flops > prior.peak_flops
    assert fit.profile.name.endswith("+cal")


def test_fit_profile_is_bounded_against_pathological_data():
    prior = cost.CPU_PROFILE
    b = cost.CostBreakdown(flops=1.0, hbm_bytes=1.0, param_bytes=0.0,
                           vmem_bytes=0.0, n_ops=1, seconds=1.0)
    fit = cost.fit_profile([(b, 1e6, 1.0)], prior)  # absurd measurement
    p = fit.profile
    assert prior.op_overhead_s / 100 <= p.op_overhead_s <= prior.op_overhead_s * 100
    assert prior.hbm_bw / 100 <= p.hbm_bw <= prior.hbm_bw * 100
    assert cost.fit_profile([], prior).n_samples == 0


def test_breakdown_scaled_rides_the_batch_axis():
    w = workloads.simple_q1(scale=0.3)
    b = cost.plan_cost_breakdown(w.plan, w.catalog, cost.CPU_PROFILE)
    s = b.scaled(8.0)
    assert s.flops == pytest.approx(8 * b.flops)
    assert s.hbm_bytes == pytest.approx(8 * b.hbm_bytes)
    assert s.param_bytes == b.param_bytes  # weights stream once
    assert s.n_ops == b.n_ops
