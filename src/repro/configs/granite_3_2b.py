"""granite-3-2b — 40L d=2048 32H(kv8) d_ff=8192 vocab=49155 GQA.
[hf:ibm-granite/granite-3.0-2b-base]"""
from repro.models.config import ModelConfig


def config():
    return ModelConfig(
        name="granite-3-2b", kind="dense", n_layers=40, d_model=2048,
        n_heads=32, n_kv_heads=8, d_ff=8192, vocab=49155, head_dim=64,
        act="swiglu", attn="gqa",
        source="hf:ibm-granite/granite-3.0-2b-base")


def smoke_config():
    return ModelConfig(
        name="granite-3-smoke", kind="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=192, vocab=128, head_dim=16,
        act="swiglu", attn="gqa", remat=False, loss_chunk=16)
