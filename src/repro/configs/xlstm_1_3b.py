"""xlstm-1.3b — 48L d=2048 4H mLSTM+sLSTM (7:1), vocab=50304.
[arXiv:2405.04517] sub-quadratic: runs long_500k."""
from repro.models.config import ModelConfig


def config():
    return ModelConfig(
        name="xlstm-1.3b", kind="xlstm", n_layers=48, d_model=2048,
        n_heads=4, n_kv_heads=4, d_ff=0, vocab=50304, slstm_every=8,
        subquadratic=True, source="arXiv:2405.04517")


def smoke_config():
    return ModelConfig(
        name="xlstm-smoke", kind="xlstm", n_layers=4, d_model=64,
        n_heads=2, n_kv_heads=2, d_ff=0, vocab=128, slstm_every=2,
        remat=False, loss_chunk=16, subquadratic=True)
