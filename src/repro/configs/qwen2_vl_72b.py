"""qwen2-vl-72b — 80L d=8192 64H(kv8) d_ff=29568 vocab=152064, M-RoPE;
vision frontend STUBBED (text backbone; pos3 ids supplied by input_specs).
[arXiv:2409.12191]"""
from repro.models.config import ModelConfig


def config():
    return ModelConfig(
        name="qwen2-vl-72b", kind="dense", n_layers=80, d_model=8192,
        n_heads=64, n_kv_heads=8, d_ff=29568, vocab=152064, head_dim=128,
        act="swiglu", attn="mrope", rope_theta=1e6, fsdp=True,
        source="arXiv:2409.12191")


def smoke_config():
    return ModelConfig(
        name="qwen2-vl-smoke", kind="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=192, vocab=128, head_dim=16,
        act="swiglu", attn="mrope", rope_theta=1e6, remat=False,
        loss_chunk=16)
