"""deepseek-v2-236b — 60L d=5120 128H MLA(kv_lora=512) MoE 2 shared + 160
routed top-6 d_expert=1536 vocab=102400. [arXiv:2405.04434; hf]"""
from repro.models.config import ModelConfig, MoEConfig, MLAConfig


def config():
    return ModelConfig(
        name="deepseek-v2-236b", kind="moe", n_layers=60, d_model=5120,
        n_heads=128, n_kv_heads=128, d_ff=0, vocab=102400, head_dim=128,
        act="swiglu", attn="mla",
        mla=MLAConfig(kv_lora=512, q_lora=1536, rope_dim=64, nope_dim=128,
                      v_dim=128),
        moe=MoEConfig(n_experts=160, top_k=6, d_expert=1536, n_shared=2,
                      d_shared=1536),
        fsdp=True, source="arXiv:2405.04434")


def smoke_config():
    return ModelConfig(
        name="deepseek-v2-smoke", kind="moe", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=0, vocab=128, head_dim=16,
        act="swiglu", attn="mla", remat=False, loss_chunk=16,
        mla=MLAConfig(kv_lora=32, q_lora=48, rope_dim=8, nope_dim=16, v_dim=16),
        moe=MoEConfig(n_experts=4, top_k=2, d_expert=32, n_shared=1,
                      d_shared=32))
