"""nemotron-4-15b — 32L d=6144 48H(kv8) d_ff=24576 vocab=256000,
squared-ReLU MLP. [arXiv:2402.16819]"""
from repro.models.config import ModelConfig


def config():
    return ModelConfig(
        name="nemotron-4-15b", kind="dense", n_layers=32, d_model=6144,
        n_heads=48, n_kv_heads=8, d_ff=24576, vocab=256000, head_dim=128,
        act="squared_relu", attn="gqa", fsdp=True,
        source="arXiv:2402.16819")


def smoke_config():
    return ModelConfig(
        name="nemotron-smoke", kind="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=256, vocab=128, head_dim=16,
        act="squared_relu", attn="gqa", remat=False, loss_chunk=16)
