"""deepseek-67b — 95L d=8192 64H(kv8) d_ff=22016 vocab=102400, llama-arch.
[arXiv:2401.02954]"""
from repro.models.config import ModelConfig


def config():
    return ModelConfig(
        name="deepseek-67b", kind="dense", n_layers=95, d_model=8192,
        n_heads=64, n_kv_heads=8, d_ff=22016, vocab=102400, head_dim=128,
        act="swiglu", attn="gqa", fsdp=True, source="arXiv:2401.02954")


def smoke_config():
    return ModelConfig(
        name="deepseek-67b-smoke", kind="dense", n_layers=3, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=192, vocab=128, head_dim=16,
        act="swiglu", attn="gqa", remat=False, loss_chunk=16)
