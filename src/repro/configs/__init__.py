"""Assigned-architecture configs (``--arch <id>``).

Each module exposes ``config()`` (the exact assigned full-scale config) and
``smoke_config()`` (a reduced same-family config for CPU smoke tests).
"""
from repro.configs import (granite_moe_1b_a400m, deepseek_v2_236b, xlstm_1_3b,
                           nemotron_4_15b, stablelm_12b, granite_3_2b,
                           deepseek_67b, seamless_m4t_medium, zamba2_1_2b,
                           qwen2_vl_72b)

ARCHS = {
    "granite-moe-1b-a400m": granite_moe_1b_a400m,
    "deepseek-v2-236b": deepseek_v2_236b,
    "xlstm-1.3b": xlstm_1_3b,
    "nemotron-4-15b": nemotron_4_15b,
    "stablelm-12b": stablelm_12b,
    "granite-3-2b": granite_3_2b,
    "deepseek-67b": deepseek_67b,
    "seamless-m4t-medium": seamless_m4t_medium,
    "zamba2-1.2b": zamba2_1_2b,
    "qwen2-vl-72b": qwen2_vl_72b,
}


def get_config(arch: str):
    return ARCHS[arch].config()


def get_smoke_config(arch: str):
    return ARCHS[arch].smoke_config()
