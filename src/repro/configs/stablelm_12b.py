"""stablelm-12b — 40L d=5120 32H(kv8) d_ff=13824 vocab=100352.
[hf:stabilityai/stablelm-2-12b family]"""
from repro.models.config import ModelConfig


def config():
    return ModelConfig(
        name="stablelm-12b", kind="dense", n_layers=40, d_model=5120,
        n_heads=32, n_kv_heads=8, d_ff=13824, vocab=100352, head_dim=160,
        act="swiglu", attn="gqa", fsdp=True,
        source="hf:stabilityai/stablelm-2-1_6b (scaled family)")


def smoke_config():
    return ModelConfig(
        name="stablelm-smoke", kind="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=192, vocab=128, head_dim=16,
        act="swiglu", attn="gqa", remat=False, loss_chunk=16)
