"""zamba2-1.2b — 38 Mamba2 layers d=2048 + ONE shared attention+MLP block
(32H kv32, d_ff=8192) applied every 6 layers; ssm_state=64.
[arXiv:2411.15242] sub-quadratic backbone: runs long_500k."""
from repro.models.config import ModelConfig


def config():
    return ModelConfig(
        name="zamba2-1.2b", kind="hybrid", n_layers=38, d_model=2048,
        n_heads=32, n_kv_heads=32, d_ff=8192, vocab=32000, head_dim=64,
        ssm_state=64, attn_every=6, subquadratic=True,
        source="arXiv:2411.15242")


def smoke_config():
    return ModelConfig(
        name="zamba2-smoke", kind="hybrid", n_layers=4, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=128, vocab=128, head_dim=16,
        ssm_state=16, attn_every=2, remat=False, loss_chunk=16,
        subquadratic=True)
