"""seamless-m4t-medium — enc-dec 12L+12L d=1024 16H(kv16) d_ff=4096
vocab=256206; audio frontend STUBBED (input_specs provides precomputed frame
embeddings). [arXiv:2308.11596]"""
from repro.models.config import ModelConfig


def config():
    return ModelConfig(
        name="seamless-m4t-medium", kind="encdec", n_layers=12, d_model=1024,
        n_heads=16, n_kv_heads=16, d_ff=4096, vocab=256206, head_dim=64,
        act="gelu", attn="gqa", enc_layers=12,
        source="arXiv:2308.11596")


def smoke_config():
    return ModelConfig(
        name="seamless-smoke", kind="encdec", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=128, vocab=128, head_dim=16,
        act="gelu", attn="gqa", enc_layers=2, remat=False, loss_chunk=16)
