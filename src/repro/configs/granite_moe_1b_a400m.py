"""granite-moe-1b-a400m — 24L d=1024 16H(kv8) MoE 32e top-8 d_expert=512
vocab=49155. [hf:ibm-granite/granite-3.0-1b-a400m-base; hf]"""
from repro.models.config import ModelConfig, MoEConfig


def config():
    return ModelConfig(
        name="granite-moe-1b-a400m", kind="moe", n_layers=24, d_model=1024,
        n_heads=16, n_kv_heads=8, d_ff=0, vocab=49155, head_dim=64,
        act="swiglu", attn="gqa",
        moe=MoEConfig(n_experts=32, top_k=8, d_expert=512),
        source="hf:ibm-granite/granite-3.0-1b-a400m-base")


def smoke_config():
    return ModelConfig(
        name="granite-moe-smoke", kind="moe", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=0, vocab=128, head_dim=16,
        act="swiglu", attn="gqa", remat=False, loss_chunk=16,
        moe=MoEConfig(n_experts=4, top_k=2, d_expert=32))
