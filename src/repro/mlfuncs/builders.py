"""High-level ML function builders + the Appendix-M model sampler.

Each builder returns an ``MLFunction`` whose ``graph`` is the bottom-level IR
(matMul/bias/act/embed/... atoms). The sampler draws random architectures
from the paper's templates (MLP, TwoTower, DLRM, CNN-as-MLP, DecisionForest,
AutoEncoder, SVD) to generate Model2Vec training data.
"""
from __future__ import annotations

from typing import List, Sequence

import numpy as np

from repro.mlfuncs.functions import Atom, MLGraph, MLNode, MLFunction


def _rng(seed):
    return np.random.default_rng(seed)


def _dense_atoms(rng, dims: Sequence[int], acts: Sequence[str]) -> List[Atom]:
    atoms: List[Atom] = []
    for i in range(len(dims) - 1):
        w = (rng.standard_normal((dims[i], dims[i + 1])) / np.sqrt(dims[i])).astype(np.float32)
        b = np.zeros((dims[i + 1],), np.float32)
        atoms.append(Atom("matmul", {"w": w}))
        atoms.append(Atom("bias", {"b": b}))
        atoms.append(Atom("act", {"fn": acts[i]}))
    return atoms


def ffnn(name: str, dims: Sequence[int], acts: Sequence[str] | None = None,
         seed: int = 0) -> MLFunction:
    """Fully connected network: matmul->bias->act per layer."""
    rng = _rng(seed)
    if acts is None:
        acts = ["relu"] * (len(dims) - 2) + ["sigmoid"]
    atoms = _dense_atoms(rng, dims, acts)
    nodes, prev = [], ("in", 0)
    for i, a in enumerate(atoms):
        nodes.append(MLNode(id=i, atom=a, args=(prev,)))
        prev = ("node", i)
    g = MLGraph(nodes=nodes, out=len(atoms) - 1, n_inputs=1)
    return MLFunction(name=name, graph=g, n_inputs=1)


def _tower_nodes(rng, nodes: List[MLNode], start_id: int, in_ref, dims, acts):
    prev = in_ref
    nid = start_id
    for i in range(len(dims) - 1):
        w = (rng.standard_normal((dims[i], dims[i + 1])) / np.sqrt(dims[i])).astype(np.float32)
        b = np.zeros((dims[i + 1],), np.float32)
        for atom in (Atom("matmul", {"w": w}), Atom("bias", {"b": b}),
                     Atom("act", {"fn": acts[i]})):
            nodes.append(MLNode(id=nid, atom=atom, args=(prev,)))
            prev = ("node", nid)
            nid += 1
    return prev, nid


def two_tower(name: str, user_dims: Sequence[int], item_dims: Sequence[int],
              seed: int = 0) -> MLFunction:
    """cosSim(userTower(in0), itemTower(in1)) — the paper's running example."""
    rng = _rng(seed)
    assert user_dims[-1] == item_dims[-1], "tower output dims must match"
    nodes: List[MLNode] = []
    acts_u = ["relu"] * (len(user_dims) - 2) + ["identity"]
    acts_i = ["relu"] * (len(item_dims) - 2) + ["identity"]
    u_ref, nid = _tower_nodes(rng, nodes, 0, ("in", 0), user_dims, acts_u)
    i_ref, nid = _tower_nodes(rng, nodes, nid, ("in", 1), item_dims, acts_i)
    nodes.append(MLNode(id=nid, atom=Atom("cossim"), args=(u_ref, i_ref)))
    g = MLGraph(nodes=nodes, out=nid, n_inputs=2)
    return MLFunction(name=name, graph=g, n_inputs=2)


def concat_ffnn(name: str, in_dims: Sequence[int], hidden: Sequence[int],
                out_act: str = "sigmoid", seed: int = 0) -> MLFunction:
    """f(concat(in0, in1, ...)) with an FFNN f — R2-1's factorizable shape."""
    rng = _rng(seed)
    nodes: List[MLNode] = []
    nodes.append(MLNode(id=0, atom=Atom("concat"),
                        args=tuple(("in", k) for k in range(len(in_dims)))))
    dims = [int(sum(in_dims))] + list(hidden)
    acts = ["relu"] * (len(dims) - 2) + [out_act]
    prev, nid = _tower_nodes(rng, nodes, 1, ("node", 0), dims, acts)
    g = MLGraph(nodes=nodes, out=nid - 1, n_inputs=len(in_dims))
    return MLFunction(name=name, graph=g, n_inputs=len(in_dims))


def autoencoder_encoder(name: str, in_dim: int, hidden: int, code: int,
                        seed: int = 0) -> MLFunction:
    """Encoder half of an autoencoder (paper Q2/Q3: dense representation)."""
    return ffnn(name, [in_dim, hidden, code], acts=["relu", "identity"], seed=seed)


def logreg(name: str, in_dim: int, seed: int = 0) -> MLFunction:
    return ffnn(name, [in_dim, 1], acts=["sigmoid"], seed=seed)


def decision_forest(name: str, n_trees: int, depth: int, n_features: int,
                    seed: int = 0) -> MLFunction:
    rng = _rng(seed)
    n_internal = 2 ** depth - 1
    feat = rng.integers(0, n_features, size=(n_trees, n_internal)).astype(np.int32)
    thresh = rng.standard_normal((n_trees, n_internal)).astype(np.float32)
    leaf = rng.standard_normal((n_trees, 2 ** depth)).astype(np.float32)
    atom = Atom("forest", {"feat": feat, "thresh": thresh, "leaf": leaf, "depth": depth})
    g = MLGraph(nodes=[MLNode(id=0, atom=atom, args=(("in", 0),))], out=0, n_inputs=1)
    return MLFunction(name=name, graph=g, n_inputs=1)


def svd_score(name: str, n_users: int, n_items: int, rank: int, seed: int = 0) -> MLFunction:
    """SVD-style score: dot(U[uid], V[mid]) over (uid, mid) id columns."""
    rng = _rng(seed)
    u = (rng.standard_normal((n_users, rank)) / np.sqrt(rank)).astype(np.float32)
    v = (rng.standard_normal((n_items, rank)) / np.sqrt(rank)).astype(np.float32)
    nodes = [
        MLNode(id=0, atom=Atom("embed", {"table": u}), args=(("in", 0),)),
        MLNode(id=1, atom=Atom("embed", {"table": v}), args=(("in", 1),)),
        MLNode(id=2, atom=Atom("dot"), args=(("node", 0), ("node", 1))),
    ]
    g = MLGraph(nodes=nodes, out=2, n_inputs=2)
    return MLFunction(name=name, graph=g, n_inputs=2)


def embedding(name: str, vocab: int, dim: int, seed: int = 0) -> MLFunction:
    rng = _rng(seed)
    table = (rng.standard_normal((vocab, dim)) / np.sqrt(dim)).astype(np.float32)
    g = MLGraph(nodes=[MLNode(id=0, atom=Atom("embed", {"table": table}),
                              args=(("in", 0),))], out=0, n_inputs=1)
    return MLFunction(name=name, graph=g, n_inputs=1)


def dlrm(name: str, dense_dim: int, emb_dim: int, top_hidden: Sequence[int],
         seed: int = 0) -> MLFunction:
    """Simplified DLRM: top_mlp(concat(bottom_mlp(dense), emb_u, emb_m)).

    Inputs: in0 dense features [N, dense_dim], in1 user emb [N, emb_dim],
    in2 item emb [N, emb_dim] (embeddings precomputed by embed atoms upstream
    or passed as feature columns).
    """
    rng = _rng(seed)
    nodes: List[MLNode] = []
    bot_ref, nid = _tower_nodes(rng, nodes, 0, ("in", 0),
                                [dense_dim, emb_dim], ["relu"])
    nodes.append(MLNode(id=nid, atom=Atom("concat"),
                        args=(bot_ref, ("in", 1), ("in", 2))))
    cat = ("node", nid)
    nid += 1
    dims = [emb_dim * 3] + list(top_hidden) + [1]
    acts = ["relu"] * (len(dims) - 2) + ["sigmoid"]
    out_ref, nid = _tower_nodes(rng, nodes, nid, cat, dims, acts)
    g = MLGraph(nodes=nodes, out=nid - 1, n_inputs=3)
    return MLFunction(name=name, graph=g, n_inputs=3)


def kmeans_assign(name: str, k: int, dim: int, seed: int = 0) -> MLFunction:
    """Distance to nearest centroid (R3-3's target computation)."""
    rng = _rng(seed)
    cents = rng.standard_normal((k, dim)).astype(np.float32)

    def fn(x):
        import jax.numpy as jnp
        d = jnp.sum(jnp.square(x[:, None, :] - jnp.asarray(cents)[None, :, :]), axis=-1)
        return jnp.argmin(d, axis=-1).astype(jnp.float32)

    # graph form: dist to each centroid via matmul trick is possible, but we
    # keep a compact opaque form + a hint; R3-3 uses the centroid table size.
    f = MLFunction(name=name, graph=None, opaque_fn=fn, n_inputs=1)
    f.centroids = cents  # type: ignore[attr-defined]
    return f


# ---------------------------------------------------------------------------
# Appendix-M random model sampler (Model2Vec training data)
# ---------------------------------------------------------------------------

TEMPLATES = ("mlp", "two_tower", "dlrm", "forest", "autoencoder", "svd", "concat_ffnn")


def sample_model(seed: int, name: str | None = None) -> MLFunction:
    rng = _rng(seed)
    t = TEMPLATES[int(rng.integers(0, len(TEMPLATES)))]
    name = name or f"sampled_{t}_{seed}"
    if t == "mlp":
        depth = int(rng.integers(1, 5))
        dims = [int(rng.integers(8, 512))] + [int(rng.integers(16, 1024)) for _ in range(depth)] + [1]
        return ffnn(name, dims, seed=seed)
    if t == "two_tower":
        code = int(rng.integers(16, 256))
        ud = [int(rng.integers(16, 512)), int(rng.integers(64, 512)), code]
        it = [int(rng.integers(16, 512)), int(rng.integers(64, 512)), code]
        return two_tower(name, ud, it, seed=seed)
    if t == "dlrm":
        return dlrm(name, int(rng.integers(8, 256)), int(rng.integers(16, 128)),
                    [int(rng.integers(32, 256))], seed=seed)
    if t == "forest":
        return decision_forest(name, int(rng.integers(8, 256)), int(rng.integers(3, 9)),
                               int(rng.integers(8, 128)), seed=seed)
    if t == "autoencoder":
        return autoencoder_encoder(name, int(rng.integers(128, 4096)),
                                   int(rng.integers(64, 2048)),
                                   int(rng.integers(16, 256)), seed=seed)
    if t == "svd":
        return svd_score(name, int(rng.integers(100, 5000)), int(rng.integers(100, 5000)),
                         int(rng.integers(8, 128)), seed=seed)
    if t == "concat_ffnn":
        k = int(rng.integers(2, 4))
        in_dims = [int(rng.integers(8, 256)) for _ in range(k)]
        hidden = [int(rng.integers(32, 512)), 1]
        return concat_ffnn(name, in_dims, hidden, seed=seed)
    raise AssertionError(t)
