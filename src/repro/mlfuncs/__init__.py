"""ML functions — the bottom-level IR vocabulary (paper Sec. III-B).

Atomic ML functions (``Atom``) are batch-apply primitives with shape/FLOPs
introspection. High-level ML functions are ``MLGraph`` compositions of atoms
(the bottom-level computation graph the optimizer can analyze), registered in
a ``Registry`` at model-loading time (paper Fig. 3, steps 1-2).
"""
from repro.mlfuncs.functions import Atom, MLGraph, MLNode, MLFunction
from repro.mlfuncs.registry import Registry
from repro.mlfuncs import builders

__all__ = ["Atom", "MLGraph", "MLNode", "MLFunction", "Registry", "builders"]
