"""ML function registry (paper Fig. 3 step 2: register computational graphs).

A Registry instance is attached to a query workload; the optimizer resolves
CALLFUNC expression nodes against it to reach the bottom-level IR.
"""
from __future__ import annotations

from typing import Dict, Iterator

from repro.mlfuncs.functions import MLFunction


class Registry:
    def __init__(self) -> None:
        self._fns: Dict[str, MLFunction] = {}

    def register(self, fn: MLFunction) -> MLFunction:
        if fn.name in self._fns:
            raise ValueError(f"duplicate ML function {fn.name}")
        self._fns[fn.name] = fn
        return fn

    def replace(self, fn: MLFunction) -> MLFunction:
        self._fns[fn.name] = fn
        return fn

    def get(self, name: str) -> MLFunction:
        return self._fns[name]

    def __contains__(self, name: str) -> bool:
        return name in self._fns

    def __iter__(self) -> Iterator[str]:
        return iter(self._fns)

    def fresh_name(self, base: str) -> str:
        i = 0
        while f"{base}_{i}" in self._fns:
            i += 1
        return f"{base}_{i}"

    def copy(self) -> "Registry":
        r = Registry()
        r._fns = dict(self._fns)
        return r
