"""Atomic ML functions, computation graphs, and high-level ML functions.

An ``Atom`` is a batched primitive (operates on [N, d] / [N] columns). Every
atom exposes ``out_dim`` and ``flops_per_row`` so the query optimizer can read
tensor shapes and costs straight off the bottom-level IR (paper Sec. III-C).

``MLGraph`` is the bottom-level IR: nodes are atoms, edges are tensors. Graph
inputs are vector/scalar columns of the enclosing relation.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Ref = Tuple[str, int]  # ('in', k) or ('node', node_id)


def _act(kind: str, x: jax.Array) -> jax.Array:
    if kind == "relu":
        return jax.nn.relu(x)
    if kind == "sigmoid":
        return jax.nn.sigmoid(x)
    if kind == "tanh":
        return jnp.tanh(x)
    if kind == "gelu":
        return jax.nn.gelu(x)
    if kind == "softmax":
        return jax.nn.softmax(x, axis=-1)
    if kind == "squared_relu":
        return jnp.square(jax.nn.relu(x))
    if kind == "identity":
        return x
    raise ValueError(f"unknown activation {kind}")


@dataclasses.dataclass
class Atom:
    """One atomic ML function instance (with bound parameters)."""

    kind: str
    params: Dict[str, object] = dataclasses.field(default_factory=dict)
    # execution backend, mutated by R4-2 (library replacement): 'jnp'|'pallas'
    backend: str = "jnp"

    # -- shape/flops introspection (dims: 0 means scalar/int column) ------
    def out_dim(self, in_dims: Sequence[int]) -> int:
        k, p = self.kind, self.params
        if k == "matmul":
            return int(p["w"].shape[1])
        if k == "bias":
            return in_dims[0]
        if k == "act":
            return in_dims[0]
        if k == "concat":
            return int(sum(max(d, 1) for d in in_dims))
        if k in ("cossim", "dot", "dist"):
            return 0
        if k == "embed":
            return int(p["table"].shape[1])
        if k == "scale":
            return in_dims[0]
        if k == "onehot":
            return int(p["num"])
        if k == "forest":
            return 0
        if k == "fused_dense":
            return int(p["w"].shape[1])
        if k == "binarize":
            return 0
        if k == "slice":
            return int(p["stop"] - p["start"])
        if k in ("add", "mul", "sqrt"):
            return in_dims[0]
        if k == "argmin":
            return 0
        if k == "const_vec":
            return int(np.asarray(p["value"]).shape[-1])
        raise ValueError(f"unknown atom kind {k}")

    def flops_per_row(self, in_dims: Sequence[int]) -> float:
        k, p = self.kind, self.params
        d = [max(x, 1) for x in in_dims] if in_dims else [1]
        if k == "matmul":
            w = p["w"]
            return 2.0 * w.shape[0] * w.shape[1]
        if k == "fused_dense":
            w = p["w"]
            return 2.0 * w.shape[0] * w.shape[1] + 2.0 * w.shape[1]
        if k in ("bias", "act", "scale", "add", "mul", "sqrt", "binarize", "argmin"):
            return float(d[0])
        if k == "concat":
            return float(sum(d))
        if k in ("cossim", "dist"):
            return 6.0 * d[0]
        if k == "dot":
            return 2.0 * d[0]
        if k == "embed":
            return float(p["table"].shape[1])  # gather cost proxy
        if k == "onehot":
            return float(p["num"])
        if k == "forest":
            return float(p["feat"].shape[0] * p["depth"] * 4)
        if k == "slice":
            return float(p["stop"] - p["start"])
        if k == "const_vec":
            return 0.0
        raise ValueError(f"unknown atom kind {k}")

    def param_bytes(self) -> int:
        total = 0
        for v in self.params.values():
            if isinstance(v, (jnp.ndarray, np.ndarray)):
                total += int(np.prod(v.shape)) * v.dtype.itemsize
        return total

    # -- execution ---------------------------------------------------------
    def apply(self, *xs: jax.Array) -> jax.Array:
        k, p = self.kind, self.params
        if k == "matmul":
            x = xs[0] if xs[0].ndim == 2 else xs[0][:, None]
            return x @ jnp.asarray(p["w"])
        if k == "fused_dense":
            if self.backend == "pallas":
                from repro.kernels.fused_dense import ops as fd_ops
                return fd_ops.fused_dense(xs[0], jnp.asarray(p["w"]),
                                          jnp.asarray(p["b"]), p["act"])
            return _act(p["act"], xs[0] @ jnp.asarray(p["w"]) + jnp.asarray(p["b"]))
        if k == "bias":
            return xs[0] + jnp.asarray(p["b"])
        if k == "act":
            return _act(p["fn"], xs[0])
        if k == "concat":
            cols = [x if x.ndim == 2 else x[:, None].astype(jnp.float32) for x in xs]
            return jnp.concatenate(cols, axis=-1)
        if k == "cossim":
            a, b = xs
            num = jnp.sum(a * b, axis=-1)
            den = jnp.linalg.norm(a, axis=-1) * jnp.linalg.norm(b, axis=-1) + 1e-8
            return num / den
        if k == "dot":
            return jnp.sum(xs[0] * xs[1], axis=-1)
        if k == "dist":
            return jnp.sqrt(jnp.sum(jnp.square(xs[0] - xs[1]), axis=-1) + 1e-12)
        if k == "embed":
            table = jnp.asarray(p["table"])
            ids = jnp.clip(xs[0].astype(jnp.int32), 0, table.shape[0] - 1)
            return table[ids]
        if k == "scale":
            return (xs[0] - jnp.asarray(p["mean"])) / (jnp.asarray(p["std"]) + 1e-8)
        if k == "onehot":
            return jax.nn.one_hot(xs[0].astype(jnp.int32), p["num"])
        if k == "binarize":
            return (xs[0] > p["threshold"]).astype(jnp.float32)
        if k == "forest":
            return _forest_apply(p, xs[0], self.backend)
        if k == "slice":
            return xs[0][:, p["start"]:p["stop"]]
        if k == "add":
            return xs[0] + xs[1]
        if k == "mul":
            return xs[0] * xs[1]
        if k == "sqrt":
            return jnp.sqrt(jnp.maximum(xs[0], 0.0))
        if k == "argmin":
            return jnp.argmin(xs[0], axis=-1).astype(jnp.float32)
        if k == "const_vec":
            v = jnp.asarray(p["value"])
            return jnp.broadcast_to(v, (xs[0].shape[0],) + v.shape)
        raise ValueError(f"unknown atom kind {k}")


def _forest_apply(p: Dict, x: jax.Array, backend: str) -> jax.Array:
    """Array-form decision forest: complete binary trees of fixed depth.

    feat[T, 2^D-1] int32, thresh[T, 2^D-1] f32, leaf[T, 2^D] f32.
    Returns mean leaf value over trees (the ensemble vote).
    """
    if backend == "pallas":
        from repro.kernels.decision_forest import ops as df_ops
        return df_ops.forest_predict(x, jnp.asarray(p["feat"]),
                                     jnp.asarray(p["thresh"]),
                                     jnp.asarray(p["leaf"]))
    feat = jnp.asarray(p["feat"])
    thresh = jnp.asarray(p["thresh"])
    leaf = jnp.asarray(p["leaf"])
    depth = int(p["depth"])
    n, t = x.shape[0], feat.shape[0]
    node = jnp.zeros((n, t), dtype=jnp.int32)
    t_idx = jnp.arange(t)[None, :]
    for _ in range(depth):
        f = feat[t_idx, node]                          # [n, t]
        th = thresh[t_idx, node]
        xv = jnp.take_along_axis(x, f, axis=1)         # gather features
        node = 2 * node + 1 + (xv > th).astype(jnp.int32)
    leaf_idx = node - (2 ** depth - 1)
    lv = leaf[t_idx, leaf_idx]
    return jnp.mean(lv, axis=1)


# ---------------------------------------------------------------------------
# computation graph (bottom-level IR)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class MLNode:
    id: int
    atom: Atom
    args: Tuple[Ref, ...]


@dataclasses.dataclass
class MLGraph:
    nodes: List[MLNode]  # topologically ordered
    out: int             # output node id
    n_inputs: int

    def node(self, nid: int) -> MLNode:
        for n in self.nodes:
            if n.id == nid:
                return n
        raise KeyError(nid)

    def apply(self, *inputs: jax.Array) -> jax.Array:
        vals: Dict[int, jax.Array] = {}
        for n in self.nodes:
            xs = [inputs[r[1]] if r[0] == "in" else vals[r[1]] for r in n.args]
            vals[n.id] = n.atom.apply(*xs)
        return vals[self.out]

    def infer_dims(self, in_dims: Sequence[int]) -> Dict[int, int]:
        dims: Dict[int, int] = {}
        for n in self.nodes:
            arg_dims = [in_dims[r[1]] if r[0] == "in" else dims[r[1]] for r in n.args]
            dims[n.id] = n.atom.out_dim(arg_dims)
        return dims

    def out_dim(self, in_dims: Sequence[int]) -> int:
        return self.infer_dims(in_dims)[self.out]

    def flops_per_row(self, in_dims: Sequence[int]) -> float:
        dims = self.infer_dims(in_dims)
        total = 0.0
        for n in self.nodes:
            arg_dims = [in_dims[r[1]] if r[0] == "in" else dims[r[1]] for r in n.args]
            total += n.atom.flops_per_row(arg_dims)
        return total

    def param_bytes(self) -> int:
        return sum(n.atom.param_bytes() for n in self.nodes)

    def input_deps(self) -> Dict[int, frozenset]:
        """node id -> set of graph-input indices it (transitively) depends on."""
        deps: Dict[int, frozenset] = {}
        for n in self.nodes:
            s = set()
            for r in n.args:
                if r[0] == "in":
                    s.add(r[1])
                else:
                    s |= deps[r[1]]
            deps[n.id] = frozenset(s)
        return deps

    def fresh_id(self) -> int:
        return max((n.id for n in self.nodes), default=-1) + 1


def chain(atoms: Sequence[Atom], n_inputs: int = 1) -> MLGraph:
    """Sequential graph: in0 -> a0 -> a1 -> ... (single input)."""
    nodes: List[MLNode] = []
    prev: Ref = ("in", 0)
    for i, a in enumerate(atoms):
        nodes.append(MLNode(id=i, atom=a, args=(prev,)))
        prev = ("node", i)
    return MLGraph(nodes=nodes, out=len(atoms) - 1, n_inputs=n_inputs)


# ---------------------------------------------------------------------------
# high-level ML function
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class MLFunction:
    """A registered (possibly analyzable) ML function.

    ``graph`` is the bottom-level IR; ``opaque_fn`` is used instead when the
    model is a true black box (paper: huggingface/llm endpoints — here backed
    by local zoo models).
    """

    name: str
    graph: Optional[MLGraph] = None
    opaque_fn: Optional[Callable[..., jax.Array]] = None
    n_inputs: int = 1
    # optional hint for selectivity when used as a boolean filter
    selectivity_hint: Optional[float] = None

    def apply(self, *inputs: jax.Array) -> jax.Array:
        if self.graph is not None:
            return self.graph.apply(*inputs)
        assert self.opaque_fn is not None, f"{self.name} has no implementation"
        return self.opaque_fn(*inputs)

    def flops_per_row(self, in_dims: Sequence[int]) -> float:
        if self.graph is not None:
            return self.graph.flops_per_row(in_dims)
        return 1e6  # unknown black box: pessimistic constant

    def out_dim(self, in_dims: Sequence[int]) -> int:
        if self.graph is not None:
            return self.graph.out_dim(in_dims)
        return 0

    def param_bytes(self) -> int:
        return self.graph.param_bytes() if self.graph is not None else 0
