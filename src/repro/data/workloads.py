"""The paper's 12 representative inference workloads as three-level-IR plans.

Each builder returns a ``Workload`` (name, Plan, Catalog, memory budget).
ML filter selectivities are measured exactly against the base data at build
time (the role of the paper's statistics/sample features), making them sound
upper bounds for Compact.
"""
from __future__ import annotations

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import ir
from repro.mlfuncs import builders
from repro.mlfuncs.registry import Registry
from repro.data import movielens, tpcxai, analytics


@dataclasses.dataclass
class Workload:
    name: str
    plan: ir.Plan
    catalog: ir.Catalog
    memory_budget: float = 512e6  # bytes; the paper's 61GB box, scaled


def roll_tables(tables, shift: int):
    """One legal parameterized instance of ``tables``: every column and the
    valid mask roll together by ``shift`` rows, so row integrity (join keys,
    masks) is preserved while the contents differ from the original. The
    canonical way tests and benchmarks fabricate same-signature traffic for
    the serving tier."""
    return jax.tree_util.tree_map(lambda x: jnp.roll(x, shift, axis=0),
                                  tables)


def rolled_instances(tables, n: int):
    """N same-schema parameterized instances (shift 0..n-1)."""
    return [roll_tables(tables, i) for i in range(n)]


def _measured_sel(fn, table_np, cols, thresh=0.5, op=">"):
    """Exact selectivity of `fn(cols...) op thresh` on the base table."""
    args = [jnp.asarray(table_np[c]) for c in cols]
    out = np.asarray(fn.apply(*args))
    if out.ndim == 2 and out.shape[1] == 1:
        out = out[:, 0]
    frac = float(np.mean(out > thresh) if op == ">" else np.mean(out < thresh))
    return min(1.0, frac + 1e-6)


# ===========================================================================
# Recommendation queries (MovieLens; paper Sec. V-C1)
# ===========================================================================

def rec_q1(scale: float = 1.0, seed: int = 0) -> Workload:
    """Q1: aggregate user/movie avg ratings, genre LIKE filter + trending
    DNN filter on movies, crossJoin users, two-tower scoring."""
    cat = movielens.build(scale, seed)
    reg = Registry()
    n_users = cat.stats["users"].rows
    n_movies = cat.stats["movies"].rows
    # user tower input: user_f(64) + avg_rating(1)=concat'd at query time via
    # vector col + scalar; towers take the 64-d and 32-d features directly
    tt = reg.register(builders.two_tower("two_tower", [64, 300, 128],
                                         [32, 300, 128], seed=seed + 1))
    trend = builders.ffnn("trending_movie_dnn", [32, 128, 64, 1], seed=seed + 2)
    reg.register(trend)
    trend.selectivity_hint = _measured_sel(trend, cat.np_tables["movies"],
                                           ["movie_f"], 0.5)

    movie_side = ir.Filter(
        ir.Filter(
            ir.Scan("movies"),
            pred=ir.IsIn(ir.Col("genre"), (1, 4, 7)),  # LIKE '%Action%'
        ),
        pred=ir.Cmp(">", ir.Call("trending_movie_dnn", (ir.Col("movie_f"),)),
                    ir.Const(0.5)),
        selectivity=trend.selectivity_hint,
    )
    user_agg = ir.Aggregate(ir.Scan("ratings"), key="r_user_id",
                            aggs=(("user_avg_rating", ("mean", "rating")),),
                            num_groups=cat.stats["users"].capacity)
    user_side = ir.Join(ir.Scan("users"), user_agg, "user_id", "r_user_id")
    q = ir.Project(
        ir.CrossJoin(user_side, movie_side),
        outputs=(("score", ir.Call("two_tower", (ir.Col("user_f"), ir.Col("movie_f")))),),
        keep=("user_id", "movie_id", "user_avg_rating"))
    return Workload("rec_q1", ir.Plan(q, reg), cat)


def rec_q2(scale: float = 1.0, seed: int = 0, tag_dim: int = 4096) -> Workload:
    """Q2: trending + user-interest DNN prefilters, join movie tags, a LARGE
    AutoEncoder compresses the tag vector (the O3/OOM driver), DLRM scores."""
    cat = movielens.build(scale, seed, tag_dim=tag_dim)
    reg = Registry()
    trend = builders.ffnn("trending_movie_dnn", [32, 128, 64, 1], seed=seed + 2)
    reg.register(trend)
    trend.selectivity_hint = _measured_sel(trend, cat.np_tables["movies"],
                                           ["movie_f"], 0.45)
    interest = builders.concat_ffnn("user_interest_dnn", [64, 32], [128, 1],
                                    seed=seed + 3)
    reg.register(interest)
    interest.selectivity_hint = 0.5
    ae = builders.autoencoder_encoder("autoencoder", tag_dim, 2048, 256,
                                      seed=seed + 4)
    reg.register(ae)
    dlrm = builders.dlrm("dlrm", 256, 64, [128], seed=seed + 5)
    reg.register(dlrm)
    emb_u = reg.register(builders.ffnn("user_emb", [64, 64],
                                       acts=["identity"], seed=seed + 6))
    emb_m = reg.register(builders.ffnn("movie_emb", [32, 64],
                                       acts=["identity"], seed=seed + 7))

    movie_side = ir.Join(
        ir.Filter(ir.Scan("movies"),
                  pred=ir.Cmp(">", ir.Call("trending_movie_dnn", (ir.Col("movie_f"),)),
                              ir.Const(0.45)),
                  selectivity=trend.selectivity_hint),
        ir.Scan("movie_tags"), "movie_id", "mt_movie_id")
    pairs = ir.Filter(
        ir.CrossJoin(ir.Scan("users"), movie_side),
        pred=ir.Cmp(">", ir.Call("user_interest_dnn",
                                 (ir.Col("user_f"), ir.Col("movie_f"))),
                    ir.Const(0.5)),
        selectivity=0.6)
    q = ir.Project(
        pairs,
        outputs=(("dense_rep", ir.Call("autoencoder", (ir.Col("mt_relevance"),))),),
        keep=("user_id", "movie_id", "user_f", "movie_f"))
    q = ir.Project(
        q,
        outputs=(("rec_score", ir.Call("dlrm", (ir.Col("dense_rep"),
                                                ir.Call("user_emb", (ir.Col("user_f"),)),
                                                ir.Call("movie_emb", (ir.Col("movie_f"),))))),),
        keep=("user_id", "movie_id"))
    return Workload("rec_q2", ir.Plan(q, reg), cat,
                    memory_budget=256e6)


def rec_q3(scale: float = 1.0, seed: int = 0, tag_dim: int = 4096) -> Workload:
    """Q3: interest + rating DNN filters, AutoEncoder dense reps for two
    movie sets, cosine-similarity vector search over the cross join."""
    cat = movielens.build(scale, seed, tag_dim=tag_dim)
    reg = Registry()
    interest = builders.concat_ffnn("user_interest_dnn", [64, 32], [128, 1],
                                    seed=seed + 3)
    reg.register(interest)
    ae = builders.autoencoder_encoder("autoencoder", tag_dim, 2048, 256,
                                      seed=seed + 4)
    reg.register(ae)
    cos = builders.two_tower("cos_sim", [256, 256], [256, 256], seed=seed + 5)
    reg.register(cos)

    left = ir.Project(
        ir.Join(
            ir.Filter(ir.Scan("movies"), pred=ir.IsIn(ir.Col("genre"), (2, 5, 9))),
            ir.Scan("movie_tags"), "movie_id", "mt_movie_id"),
        outputs=(("dense1", ir.Call("autoencoder", (ir.Col("mt_relevance"),))),),
        keep=("movie_id",))
    right = ir.Project(
        ir.Scan("movie_tags"),
        outputs=(("dense2", ir.Call("autoencoder", (ir.Col("mt_relevance"),))),),
        keep=("mt_movie_id",))
    q = ir.Project(
        ir.CrossJoin(left, right),
        outputs=(("relevant_score", ir.Call("cos_sim", (ir.Col("dense1"), ir.Col("dense2")))),),
        keep=("movie_id", "mt_movie_id"))
    return Workload("rec_q3", ir.Plan(q, reg), cat, memory_budget=256e6)


# ===========================================================================
# Retailing-Complex queries (TPCx-AI; paper Sec. V-C2)
# ===========================================================================

def retail_q1(scale: float = 1.0, seed: int = 0) -> Workload:
    """Q1: order x store join, is_popular_store ML filter, trip classifier
    FFNN over concat(order_f, store_f) — the R2-1 factorization target."""
    cat = tpcxai.build(scale, seed)
    reg = Registry()
    pop = builders.ffnn("is_popular_store", [24, 32, 1], seed=seed + 1)
    reg.register(pop)
    pop.selectivity_hint = _measured_sel(pop, cat.np_tables["store"],
                                         ["store_f"], 0.5)
    clf = builders.concat_ffnn("trip_classifier_dnn", [40, 24], [48, 32, 1],
                               seed=seed + 2)
    reg.register(clf)

    q = ir.Project(
        ir.Filter(
            ir.Filter(
                ir.Join(ir.Scan("order"), ir.Scan("store"), "o_store", "store"),
                pred=ir.Cmp("!=", ir.Col("weekday"), ir.Const(6))),
            pred=ir.Cmp(">", ir.Call("is_popular_store", (ir.Col("store_f"),)),
                        ir.Const(0.5)),
            selectivity=pop.selectivity_hint),
        outputs=(("trip_class", ir.Call("trip_classifier_dnn",
                                        (ir.Col("order_f"), ir.Col("store_f")))),),
        keep=("o_order_id",))
    return Workload("retail_q1", ir.Plan(q, reg), cat)


def retail_q2(scale: float = 1.0, seed: int = 0) -> Workload:
    """Q2: per-customer aggregates joined with transactions + accounts;
    XGBoost forest AND DNN must both flag fraud — the R3-2 target."""
    cat = tpcxai.build(scale, seed)
    reg = Registry()
    xgb = builders.decision_forest("xgboost_fraud", n_trees=160, depth=6,
                                   n_features=32, seed=seed + 1)
    reg.register(xgb)
    dnn = builders.concat_ffnn("dnn_fraud", [20, 12], [12, 1], seed=seed + 2)
    reg.register(dnn)

    cust = ir.Join(ir.Scan("customer"), ir.Scan("financial_account"),
                   "c_customer_sk", "fa_customer_sk")
    cust = ir.Filter(cust, pred=ir.Cmp("==", ir.Col("c_cust_flag"), ir.Const(0)))
    joined = ir.Join(ir.Scan("financial_transactions"), cust,
                     "senderID", "c_customer_sk")
    joined = ir.Filter(joined, pred=ir.Cmp(">", ir.Col("amount"), ir.Const(100.0)))
    feat = ir.Project(
        joined,
        outputs=(("fraud_feat", ir.Call("concat2_q2", (ir.Col("customer_f"), ir.Col("txn_f")))),),
        keep=("transactionID", "customer_f", "txn_f"))
    concat2 = builders.concat_ffnn("concat2_q2", [20, 12], [32, 32],
                                   out_act="identity", seed=seed + 3)
    reg.register(concat2)
    q = ir.Filter(
        ir.Project(
            feat,
            outputs=(("xg_score", ir.Call("xgboost_fraud", (ir.Col("fraud_feat"),))),
                     ("dnn_score", ir.Call("dnn_fraud", (ir.Col("customer_f"), ir.Col("txn_f"))))),
            keep=("transactionID",)),
        pred=ir.BoolOp("and", (
            ir.Cmp(">=", ir.Col("xg_score"), ir.Const(0.0)),
            ir.Cmp(">", ir.Col("dnn_score"), ir.Const(0.5)))))
    return Workload("retail_q2", ir.Plan(q, reg), cat)


def retail_q3(scale: float = 1.0, seed: int = 0) -> Workload:
    """Q3: aggregate product ratings, join products, crossJoin customers,
    two-tower product-customer ranking (the paper's biggest speedup)."""
    cat = tpcxai.build(scale, seed)
    reg = Registry()
    tt = builders.two_tower("two_tower_retail", [20, 128, 40, 16],
                            [25, 128, 40, 16], seed=seed + 1)
    reg.register(tt)

    prod_agg = ir.Aggregate(ir.Scan("product_rating"), key="pr_product_id",
                            aggs=(("prod_avg_rating", ("mean", "pr_rating")),),
                            num_groups=cat.stats["product"].capacity)
    prod = ir.Filter(
        ir.Join(ir.Scan("product"), prod_agg, "p_product_id", "pr_product_id"),
        pred=ir.Cmp(">=", ir.Col("prod_avg_rating"), ir.Const(3.0)))
    q = ir.Project(
        ir.CrossJoin(ir.Scan("customer"), prod),
        outputs=(("rank_score", ir.Call("two_tower_retail",
                                        (ir.Col("customer_f"), ir.Col("product_f")))),),
        keep=("c_customer_sk", "p_product_id"))
    return Workload("retail_q3", ir.Plan(q, reg), cat)


# ===========================================================================
# Retailing-Simplified queries (paper Sec. V-C3)
# ===========================================================================

def simple_q1(scale: float = 1.0, seed: int = 0) -> Workload:
    """SVD product-rating factorization scoring."""
    cat = tpcxai.build(scale, seed)
    reg = Registry()
    svd = builders.svd_score("svd", cat.stats["customer"].capacity,
                             cat.stats["product"].capacity, 64, seed=seed + 1)
    reg.register(svd)
    q = ir.Project(ir.Scan("product_rating"),
                   outputs=(("pred_rating", ir.Call("svd", (ir.Col("pr_user_id"),
                                                            ir.Col("pr_product_id")))),),
                   keep=("pr_user_id", "pr_product_id", "pr_rating"))
    return Workload("simple_q1", ir.Plan(q, reg), cat)


def simple_q2(scale: float = 1.0, seed: int = 0) -> Workload:
    """50-tree XGBoost trip classification over store x order join."""
    cat = tpcxai.build(scale, seed)
    reg = Registry()
    xgb = builders.decision_forest("xgboost_trip", n_trees=50, depth=6,
                                   n_features=40, seed=seed + 1)
    reg.register(xgb)
    q = ir.Project(
        ir.Join(ir.Scan("order"), ir.Scan("store"), "o_store", "store"),
        outputs=(("trip_type", ir.Call("xgboost_trip", (ir.Col("order_f"),))),),
        keep=("o_order_id",))
    return Workload("simple_q2", ir.Plan(q, reg), cat)


def simple_q3(scale: float = 1.0, seed: int = 0) -> Workload:
    """Logistic-regression fraud detection over account x transaction join."""
    cat = tpcxai.build(scale, seed)
    reg = Registry()
    lr = builders.concat_ffnn("logreg_fraud", [12, 1, 1], [1], seed=seed + 1)
    reg.register(lr)
    joined = ir.Join(ir.Scan("financial_transactions"), ir.Scan("financial_account"),
                     "senderID", "fa_customer_sk")
    q = ir.Project(
        joined,
        outputs=(("fraud_prob", ir.Call("logreg_fraud",
                                        (ir.Col("txn_f"), ir.Col("amount"),
                                         ir.Col("transaction_limit")))),),
        keep=("transactionID",))
    return Workload("simple_q3", ir.Plan(q, reg), cat)


# ===========================================================================
# Analytics queries (paper Sec. V-C4)
# ===========================================================================

def analytics_q1(scale: float = 1.0, seed: int = 0) -> Workload:
    """Credit Card fraud: single scan, predicate filters, scaler, 100-tree
    depth-9 ensemble."""
    cat = analytics.build_creditcard(scale, seed)
    reg = Registry()
    forest = builders.decision_forest("cc_forest", n_trees=100, depth=9,
                                      n_features=29, seed=seed + 1)
    reg.register(forest)
    q = ir.Project(
        ir.Filter(
            ir.Filter(ir.Scan("creditcard"),
                      pred=ir.Cmp("<", ir.Col("amount"), ir.Const(800.0))),
            pred=ir.Cmp(">", ir.Col("time"), ir.Const(2.0))),
        outputs=(("fraud", ir.Call("cc_forest", (ir.Col("cc_f"),))),),
        keep=("cc_id",))
    return Workload("analytics_q1", ir.Plan(q, reg), cat)


def analytics_q2(scale: float = 1.0, seed: int = 0) -> Workload:
    """Expedia hotel ranking: 3-way join + single deep decision tree."""
    cat = analytics.build_expedia(scale, seed)
    reg = Registry()
    tree = builders.decision_forest("exp_tree", n_trees=1, depth=9,
                                    n_features=96, seed=seed + 1)
    reg.register(tree)
    j = ir.Join(ir.Join(ir.Scan("listings"), ir.Scan("hotel"), "l_hotel_id", "h_id"),
                ir.Scan("search"), "l_search_id", "s_id")
    q = ir.Project(
        ir.Filter(
            ir.Filter(j, pred=ir.Cmp("<", ir.Col("price"), ir.Const(400.0))),
            pred=ir.Cmp(">=", ir.Col("stars"), ir.Const(2.0))),
        outputs=(("rank", ir.Call("exp_tree", (ir.Col("listing_f"),))),),
        keep=("l_id",))
    return Workload("analytics_q2", ir.Plan(q, reg), cat)


def analytics_q3(scale: float = 1.0, seed: int = 0) -> Workload:
    """Flights codeshare: 4-way join + 100-tree ensemble."""
    cat = analytics.build_flights(scale, seed)
    reg = Registry()
    forest = builders.decision_forest("fl_forest", n_trees=100, depth=9,
                                      n_features=128, seed=seed + 1)
    reg.register(forest)
    j = ir.Join(
        ir.Join(
            ir.Join(ir.Scan("routes"), ir.Scan("airlines"), "rt_airline", "al_id"),
            ir.Scan("src_airports"), "rt_src", "sa_id"),
        ir.Scan("dst_airports"), "rt_dst", "da_id")
    q = ir.Project(
        ir.Filter(
            ir.Filter(j, pred=ir.Cmp("==", ir.Col("active"), ir.Const(1))),
            pred=ir.Cmp("<", ir.Col("stops"), ir.Const(2.0))),
        outputs=(("codeshare", ir.Call("fl_forest", (ir.Col("route_f"),))),),
        keep=("rt_id",))
    return Workload("analytics_q3", ir.Plan(q, reg), cat)


ALL_WORKLOADS = {
    "rec_q1": rec_q1, "rec_q2": rec_q2, "rec_q3": rec_q3,
    "retail_q1": retail_q1, "retail_q2": retail_q2, "retail_q3": retail_q3,
    "simple_q1": simple_q1, "simple_q2": simple_q2, "simple_q3": simple_q3,
    "analytics_q1": analytics_q1, "analytics_q2": analytics_q2,
    "analytics_q3": analytics_q3,
}
