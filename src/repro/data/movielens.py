"""Synthetic MovieLens-shaped catalog (users, movies, ratings, tag relevance).

Proportions follow MovieLens-1M (6040 users / ~3900 movies / 1M ratings /
140,979-dim tag-relevance vectors from ML-32M), scaled by ``scale`` so the
engine runs interactively on CPU; scale=1.0 keeps the 3:2 user:movie ratio
with a 60x row reduction and a tag dimension of 4096.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core.ir import Catalog
from repro.relational.table import Table

N_GENRES = 18  # MovieLens genre count


def build(scale: float = 1.0, seed: int = 0, tag_dim: int = 4096):
    rng = np.random.default_rng(seed)
    n_users = max(32, int(100 * scale))
    n_movies = max(24, int(66 * scale))
    n_ratings = max(128, int(1650 * scale))

    users = Table.from_columns({
        "user_id": jnp.arange(n_users, dtype=jnp.int32),
        "gender": jnp.asarray(rng.integers(0, 2, n_users), jnp.int32),
        "age": jnp.asarray(rng.integers(18, 80, n_users), jnp.float32),
        "occupation": jnp.asarray(rng.integers(0, 21, n_users), jnp.int32),
        "user_f": jnp.asarray(rng.standard_normal((n_users, 64)) * 0.5, jnp.float32),
    })
    movies = Table.from_columns({
        "movie_id": jnp.arange(n_movies, dtype=jnp.int32),
        "genre": jnp.asarray(rng.integers(0, N_GENRES, n_movies), jnp.int32),
        "year": jnp.asarray(rng.integers(1950, 2003, n_movies), jnp.float32),
        "movie_f": jnp.asarray(rng.standard_normal((n_movies, 32)) * 0.5, jnp.float32),
    })
    ratings = Table.from_columns({
        "r_user_id": jnp.asarray(rng.integers(0, n_users, n_ratings), jnp.int32),
        "r_movie_id": jnp.asarray(rng.integers(0, n_movies, n_ratings), jnp.int32),
        "rating": jnp.asarray(rng.integers(1, 6, n_ratings), jnp.float32),
    })
    # per-movie sparse tag-relevance vectors (high-dimensional; the paper's
    # AutoEncoder compresses these — the O3 memory story)
    tags = rng.standard_normal((n_movies, tag_dim)).astype(np.float32)
    tags *= (rng.random((n_movies, tag_dim)) < 0.05)  # sparse relevance
    movie_tags = Table.from_columns({
        "mt_movie_id": jnp.arange(n_movies, dtype=jnp.int32),
        "mt_relevance": jnp.asarray(tags),
    })

    cat = Catalog()
    cat.add("users", users)
    cat.add("movies", movies)
    cat.add("ratings", ratings)
    cat.add("movie_tags", movie_tags)
    return cat
