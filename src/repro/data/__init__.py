"""Synthetic datasets + the paper's benchmark workloads.

movielens.py / tpcxai.py / analytics.py generate deterministic synthetic
catalogs shaped like the paper's datasets (MovieLens-1M, TPCx-AI, Credit
Card / Expedia / Flights), scaled for this container; workloads.py builds
the 12 representative inference queries; templates.py samples the 20-template
random query fleet (Appendix N).
"""
