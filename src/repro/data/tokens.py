"""Deterministic synthetic token pipeline for LM training.

A host-side generator produces Zipf-distributed token streams with a simple
Markov structure (so a real model can measurably learn), sharded by
(host_id, num_hosts) so every data-parallel worker reads a disjoint slice —
the same contract a production loader (grain/tf.data) would satisfy. Fully
seekable: ``state`` is just (seed, step), which is what checkpoint/resume
stores.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Tuple

import numpy as np


@dataclasses.dataclass
class TokenPipeline:
    vocab: int
    batch: int
    seq: int
    seed: int = 0
    host_id: int = 0
    num_hosts: int = 1
    step: int = 0

    def _rng_for(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            (self.seed * 1_000_003 + step) * 131 + self.host_id)

    def next_batch(self) -> Dict[str, np.ndarray]:
        rng = self._rng_for(self.step)
        self.step += 1
        b = self.batch // self.num_hosts
        v = self.vocab - 1
        # noisy affine bigram: token_{t+1} = (a*token_t + c) mod v with 15%
        # random resets — a learnable next-token function so training loss
        # measurably drops below the unigram entropy
        tokens = np.empty((b, self.seq), np.int64)
        tokens[:, 0] = rng.integers(0, v, b)
        noise = rng.random((b, self.seq)) < 0.15
        rand = rng.integers(0, v, (b, self.seq))
        for t in range(1, self.seq):
            nxt = (tokens[:, t - 1] * 31 + 7) % v
            tokens[:, t] = np.where(noise[:, t], rand[:, t], nxt)
        tokens = tokens.astype(np.int32)
        labels = np.concatenate([tokens[:, 1:],
                                 np.full((b, 1), -1, np.int32)], axis=1)
        return {"tokens": tokens, "labels": labels}

    def state(self) -> Tuple[int, int]:
        return (self.seed, self.step)

    def restore(self, state: Tuple[int, int]) -> None:
        self.seed, self.step = state
