"""Synthetic TPCx-AI-shaped retailing catalog (order, store, customer,
financial accounts/transactions, product, product_rating)."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core.ir import Catalog
from repro.relational.table import Table


def build(scale: float = 1.0, seed: int = 1):
    rng = np.random.default_rng(seed)
    n_store = max(8, int(12 * scale))
    n_order = max(64, int(800 * scale))
    n_cust = max(32, int(200 * scale))
    n_txn = max(64, int(900 * scale))
    n_prod = max(24, int(80 * scale))
    n_rate = max(64, int(1200 * scale))

    store = Table.from_columns({
        "store": jnp.arange(n_store, dtype=jnp.int32),
        "store_f": jnp.asarray(rng.standard_normal((n_store, 24)) * 0.5, jnp.float32),
    })
    order = Table.from_columns({
        "o_order_id": jnp.arange(n_order, dtype=jnp.int32),
        "o_store": jnp.asarray(rng.integers(0, n_store, n_order), jnp.int32),
        "o_customer_sk": jnp.asarray(rng.integers(0, n_cust, n_order), jnp.int32),
        "weekday": jnp.asarray(rng.integers(0, 7, n_order), jnp.int32),
        "order_f": jnp.asarray(rng.standard_normal((n_order, 40)) * 0.5, jnp.float32),
    })
    customer = Table.from_columns({
        "c_customer_sk": jnp.arange(n_cust, dtype=jnp.int32),
        "c_cust_flag": jnp.asarray(rng.integers(0, 2, n_cust), jnp.int32),
        "c_birth_year": jnp.asarray(rng.integers(1940, 2005, n_cust), jnp.float32),
        "customer_f": jnp.asarray(rng.standard_normal((n_cust, 20)) * 0.5, jnp.float32),
    })
    account = Table.from_columns({
        "fa_customer_sk": jnp.arange(n_cust, dtype=jnp.int32),
        "transaction_limit": jnp.asarray(rng.random(n_cust) * 1e4, jnp.float32),
    })
    txn = Table.from_columns({
        "transactionID": jnp.arange(n_txn, dtype=jnp.int32),
        "senderID": jnp.asarray(rng.integers(0, n_cust, n_txn), jnp.int32),
        "amount": jnp.asarray(rng.random(n_txn) * 5e3, jnp.float32),
        "hour": jnp.asarray(rng.integers(0, 24, n_txn), jnp.float32),
        "txn_f": jnp.asarray(rng.standard_normal((n_txn, 12)) * 0.5, jnp.float32),
    })
    product = Table.from_columns({
        "p_product_id": jnp.arange(n_prod, dtype=jnp.int32),
        "department": jnp.asarray(rng.integers(0, 10, n_prod), jnp.int32),
        "product_f": jnp.asarray(rng.standard_normal((n_prod, 25)) * 0.5, jnp.float32),
    })
    rating = Table.from_columns({
        "pr_user_id": jnp.asarray(rng.integers(0, n_cust, n_rate), jnp.int32),
        "pr_product_id": jnp.asarray(rng.integers(0, n_prod, n_rate), jnp.int32),
        "pr_rating": jnp.asarray(rng.integers(1, 6, n_rate), jnp.float32),
    })

    cat = Catalog()
    cat.add("store", store)
    cat.add("order", order)
    cat.add("customer", customer)
    cat.add("financial_account", account)
    cat.add("financial_transactions", txn)
    cat.add("product", product)
    cat.add("product_rating", rating)
    return cat
