"""The 20 inference-query templates (Appendix N): 10 MovieLens + 10 TPCx-AI.

Each template samples a query with varying model architectures (layer/neuron
counts), filter predicates, and selectivities. ``sample_query(template_id,
seed)`` returns (Plan, catalog_key); catalogs are shared per dataset family.
Templates are split 14 in-distribution / 6 out-of-distribution exactly as in
Sec. V-C5 (OOD chosen by seed).
"""
from __future__ import annotations

from typing import Dict, Tuple

import numpy as np

from repro.core import ir
from repro.mlfuncs import builders
from repro.mlfuncs.registry import Registry
from repro.data import movielens, tpcxai

_CATALOGS: Dict[str, ir.Catalog] = {}


def catalog(kind: str, scale: float = 1.0) -> ir.Catalog:
    key = f"{kind}@{scale}"
    if key not in _CATALOGS:
        if kind == "ml":
            _CATALOGS[key] = movielens.build(scale, seed=7, tag_dim=1024)
        else:
            _CATALOGS[key] = tpcxai.build(scale, seed=11)
    return _CATALOGS[key]


def _ffnn_dims(rng, d_in, out=1):
    depth = int(rng.integers(1, 4))
    return [d_in] + [int(rng.integers(32, 256)) for _ in range(depth)] + [out]


# -------------------- MovieLens templates (1-10) ---------------------------

def _ml_t1(rng, cat, reg):  # two-tower pre-ranking (paper Q1)
    code = int(rng.integers(32, 128))
    tt = reg.register(builders.two_tower(
        "tt", [64, int(rng.integers(128, 400)), code],
        [32, int(rng.integers(128, 400)), code], seed=int(rng.integers(1e6))))
    trend = reg.register(builders.ffnn("trend", _ffnn_dims(rng, 32),
                                       seed=int(rng.integers(1e6))))
    trend.selectivity_hint = 0.5
    genres = tuple(rng.choice(18, size=int(rng.integers(1, 4)), replace=False).tolist())
    movie = ir.Filter(
        ir.Filter(ir.Scan("movies"), pred=ir.IsIn(ir.Col("genre"), genres)),
        pred=ir.Cmp(">", ir.Call("trend", (ir.Col("movie_f"),)),
                    ir.Const(float(rng.uniform(0.3, 0.7)))))
    return ir.Project(ir.CrossJoin(ir.Scan("users"), movie),
                      outputs=(("score", ir.Call("tt", (ir.Col("user_f"),
                                                        ir.Col("movie_f")))),),
                      keep=("user_id", "movie_id"))


def _ml_t2(rng, cat, reg):  # autoencoder + DLRM (paper Q2 family)
    code = int(rng.integers(64, 256))
    ae = reg.register(builders.autoencoder_encoder(
        "ae", 1024, int(rng.integers(512, 2048)), code, seed=int(rng.integers(1e6))))
    emb_u = reg.register(builders.ffnn("eu", [64, 64], acts=["identity"],
                                       seed=int(rng.integers(1e6))))
    emb_m = reg.register(builders.ffnn("em", [32, 64], acts=["identity"],
                                       seed=int(rng.integers(1e6))))
    dl = reg.register(builders.dlrm("dl", code, 64,
                                    [int(rng.integers(64, 256))],
                                    seed=int(rng.integers(1e6))))
    movie = ir.Join(ir.Scan("movies"), ir.Scan("movie_tags"),
                    "movie_id", "mt_movie_id")
    pairs = ir.Filter(ir.CrossJoin(ir.Scan("users"), movie),
                      pred=ir.Cmp(">", ir.Col("age"),
                                  ir.Const(float(rng.integers(25, 60)))))
    q = ir.Project(pairs, outputs=(("dense", ir.Call("ae", (ir.Col("mt_relevance"),))),),
                   keep=("user_id", "movie_id", "user_f", "movie_f"))
    return ir.Project(q, outputs=(("score", ir.Call("dl", (
        ir.Col("dense"), ir.Call("eu", (ir.Col("user_f"),)),
        ir.Call("em", (ir.Col("movie_f"),))))),), keep=("user_id", "movie_id"))


def _ml_t3(rng, cat, reg):  # dense-rep cosine search (paper Q3 family)
    code = int(rng.integers(64, 256))
    ae = reg.register(builders.autoencoder_encoder(
        "ae", 1024, int(rng.integers(256, 1024)), code, seed=int(rng.integers(1e6))))
    cos = reg.register(builders.two_tower("cos", [code, code], [code, code],
                                          seed=int(rng.integers(1e6))))
    genres = tuple(rng.choice(18, size=2, replace=False).tolist())
    left = ir.Project(
        ir.Join(ir.Filter(ir.Scan("movies"), pred=ir.IsIn(ir.Col("genre"), genres)),
                ir.Scan("movie_tags"), "movie_id", "mt_movie_id"),
        outputs=(("d1", ir.Call("ae", (ir.Col("mt_relevance"),))),),
        keep=("movie_id",))
    right = ir.Project(ir.Scan("movie_tags"),
                       outputs=(("d2", ir.Call("ae", (ir.Col("mt_relevance"),))),),
                       keep=("mt_movie_id",))
    return ir.Project(ir.CrossJoin(left, right),
                      outputs=(("rel", ir.Call("cos", (ir.Col("d1"), ir.Col("d2")))),),
                      keep=("movie_id", "mt_movie_id"))


def _ml_t4(rng, cat, reg):  # rating prediction over cross join
    f = reg.register(builders.concat_ffnn("rate", [64, 32],
                                          _ffnn_dims(rng, 96)[1:],
                                          seed=int(rng.integers(1e6))))
    pred = ir.Cmp(">", ir.Col("age"), ir.Const(float(rng.integers(20, 60))))
    return ir.Project(ir.Filter(ir.CrossJoin(ir.Scan("users"), ir.Scan("movies")),
                                pred=pred),
                      outputs=(("rating", ir.Call("rate", (ir.Col("user_f"),
                                                           ir.Col("movie_f")))),),
                      keep=("user_id", "movie_id"))


def _ml_t5(rng, cat, reg):  # user opinion over users only
    f = reg.register(builders.ffnn("opinion", _ffnn_dims(rng, 64, out=3),
                                   acts=None, seed=int(rng.integers(1e6))))
    return ir.Project(
        ir.Filter(ir.Scan("users"),
                  pred=ir.Cmp("<", ir.Col("occupation"),
                              ir.Const(float(rng.integers(5, 20))))),
        outputs=(("opinion", ir.Call("opinion", (ir.Col("user_f"),))),),
        keep=("user_id",))


def _ml_t6(rng, cat, reg):  # SVD recommendation
    svd = reg.register(builders.svd_score(
        "svd", cat.stats["users"].capacity, cat.stats["movies"].capacity,
        int(rng.integers(16, 128)), seed=int(rng.integers(1e6))))
    return ir.Project(ir.Filter(ir.CrossJoin(ir.Scan("users"), ir.Scan("movies")),
                                pred=ir.IsIn(ir.Col("genre"),
                                             tuple(rng.choice(18, 3, replace=False).tolist()))),
                      outputs=(("pred", ir.Call("svd", (ir.Col("user_id"),
                                                        ir.Col("movie_id")))),),
                      keep=("user_id", "movie_id"))


def _ml_t7(rng, cat, reg):  # collaborative filtering on rating rows
    svd = reg.register(builders.svd_score(
        "cf", cat.stats["users"].capacity, cat.stats["movies"].capacity,
        int(rng.integers(16, 96)), seed=int(rng.integers(1e6))))
    return ir.Project(ir.Scan("ratings"),
                      outputs=(("pred", ir.Call("cf", (ir.Col("r_user_id"),
                                                       ir.Col("r_movie_id")))),),
                      keep=("r_user_id", "r_movie_id", "rating"))


def _ml_t8(rng, cat, reg):  # autoencoder dense rep per movie
    ae = reg.register(builders.autoencoder_encoder(
        "ae8", 1024, int(rng.integers(256, 1024)), int(rng.integers(32, 128)),
        seed=int(rng.integers(1e6))))
    return ir.Project(ir.Scan("movie_tags"),
                      outputs=(("dense", ir.Call("ae8", (ir.Col("mt_relevance"),))),),
                      keep=("mt_movie_id",))


def _ml_t9(rng, cat, reg):  # stereotype DNN over ratings x movies join
    f = reg.register(builders.ffnn("ster", _ffnn_dims(rng, 32),
                                   seed=int(rng.integers(1e6))))
    j = ir.Join(ir.Scan("ratings"), ir.Scan("movies"), "r_movie_id", "movie_id")
    return ir.Project(
        ir.Filter(j, pred=ir.Cmp(">", ir.Col("rating"),
                                 ir.Const(float(rng.integers(2, 5))))),
        outputs=(("flag", ir.Call("ster", (ir.Col("movie_f"),))),),
        keep=("r_user_id", "r_movie_id"))


def _ml_t10(rng, cat, reg):  # rating prediction, user x movie
    f = reg.register(builders.concat_ffnn("rp", [64, 32],
                                          _ffnn_dims(rng, 96)[1:],
                                          seed=int(rng.integers(1e6))))
    return ir.Project(
        ir.Filter(ir.CrossJoin(ir.Scan("users"), ir.Scan("movies")),
                  pred=ir.BoolOp("and", (
                      ir.Cmp(">", ir.Col("age"), ir.Const(float(rng.integers(20, 50)))),
                      ir.Cmp("<", ir.Col("year"), ir.Const(float(rng.integers(1970, 2002))))))),
        outputs=(("rating", ir.Call("rp", (ir.Col("user_f"), ir.Col("movie_f")))),),
        keep=("user_id", "movie_id"))


# -------------------- TPCx-AI templates (11-20) -----------------------------

def _tp_t1(rng, cat, reg):  # trip classification (retail q1 family)
    pop = reg.register(builders.ffnn("pop", _ffnn_dims(rng, 24),
                                     seed=int(rng.integers(1e6))))
    pop.selectivity_hint = 0.5
    clf = reg.register(builders.concat_ffnn("clf", [40, 24],
                                            _ffnn_dims(rng, 64)[1:],
                                            seed=int(rng.integers(1e6))))
    return ir.Project(
        ir.Filter(
            ir.Filter(ir.Join(ir.Scan("order"), ir.Scan("store"), "o_store", "store"),
                      pred=ir.Cmp("!=", ir.Col("weekday"),
                                  ir.Const(float(rng.integers(0, 7))))),
            pred=ir.Cmp(">", ir.Call("pop", (ir.Col("store_f"),)),
                        ir.Const(float(rng.uniform(0.3, 0.7))))),
        outputs=(("trip", ir.Call("clf", (ir.Col("order_f"), ir.Col("store_f")))),),
        keep=("o_order_id",))


def _tp_t2(rng, cat, reg):  # dual-model fraud (retail q2 family)
    xgb = reg.register(builders.decision_forest(
        "xgb", int(rng.integers(32, 200)), int(rng.integers(4, 7)), 32,
        seed=int(rng.integers(1e6))))
    feat = reg.register(builders.concat_ffnn("ff", [20, 12], [32, 32],
                                             out_act="identity",
                                             seed=int(rng.integers(1e6))))
    dnn = reg.register(builders.concat_ffnn("dnn", [20, 12],
                                            _ffnn_dims(rng, 32)[1:],
                                            seed=int(rng.integers(1e6))))
    cust = ir.Join(ir.Scan("customer"), ir.Scan("financial_account"),
                   "c_customer_sk", "fa_customer_sk")
    j = ir.Join(ir.Scan("financial_transactions"), cust, "senderID", "c_customer_sk")
    j = ir.Filter(j, pred=ir.Cmp(">", ir.Col("amount"),
                                 ir.Const(float(rng.integers(50, 2000)))))
    q = ir.Project(j, outputs=(("fx", ir.Call("ff", (ir.Col("customer_f"),
                                                     ir.Col("txn_f")))),),
                   keep=("transactionID", "customer_f", "txn_f"))
    return ir.Project(q, outputs=(
        ("xg", ir.Call("xgb", (ir.Col("fx"),))),
        ("dn", ir.Call("dnn", (ir.Col("customer_f"), ir.Col("txn_f"))))),
        keep=("transactionID",))


def _tp_t3(rng, cat, reg):  # two-tower product ranking (retail q3 family)
    code = int(rng.integers(8, 32))
    tt = reg.register(builders.two_tower(
        "ttp", [20, int(rng.integers(64, 256)), code],
        [25, int(rng.integers(64, 256)), code], seed=int(rng.integers(1e6))))
    agg = ir.Aggregate(ir.Scan("product_rating"), key="pr_product_id",
                       aggs=(("avg_r", ("mean", "pr_rating")),),
                       num_groups=cat.stats["product"].capacity)
    prod = ir.Filter(ir.Join(ir.Scan("product"), agg, "p_product_id", "pr_product_id"),
                     pred=ir.Cmp(">=", ir.Col("avg_r"),
                                 ir.Const(float(rng.uniform(2.0, 4.0)))))
    return ir.Project(ir.CrossJoin(ir.Scan("customer"), prod),
                      outputs=(("rank", ir.Call("ttp", (ir.Col("customer_f"),
                                                        ir.Col("product_f")))),),
                      keep=("c_customer_sk", "p_product_id"))


def _tp_t4(rng, cat, reg):  # SVD product rating
    svd = reg.register(builders.svd_score(
        "svdp", cat.stats["customer"].capacity, cat.stats["product"].capacity,
        int(rng.integers(16, 96)), seed=int(rng.integers(1e6))))
    j = ir.Join(ir.Scan("product_rating"), ir.Scan("product"),
                "pr_product_id", "p_product_id")
    return ir.Project(
        ir.Filter(j, pred=ir.Cmp("<", ir.Col("department"),
                                 ir.Const(float(rng.integers(3, 9))))),
        outputs=(("pred", ir.Call("svdp", (ir.Col("pr_user_id"),
                                           ir.Col("pr_product_id")))),),
        keep=("pr_user_id", "pr_product_id"))


def _tp_t5(rng, cat, reg):  # spam/anomaly detection on transactions
    f = reg.register(builders.ffnn("spam", _ffnn_dims(rng, 12),
                                   seed=int(rng.integers(1e6))))
    return ir.Project(
        ir.Filter(ir.Scan("financial_transactions"),
                  pred=ir.Cmp(">", ir.Col("hour"),
                              ir.Const(float(rng.integers(4, 20))))),
        outputs=(("spam", ir.Call("spam", (ir.Col("txn_f"),))),),
        keep=("transactionID",))


def _tp_t6(rng, cat, reg):  # trip classification forest
    forest = reg.register(builders.decision_forest(
        "tripf", int(rng.integers(20, 120)), int(rng.integers(4, 8)), 40,
        seed=int(rng.integers(1e6))))
    return ir.Project(
        ir.Join(ir.Scan("order"), ir.Scan("store"), "o_store", "store"),
        outputs=(("trip", ir.Call("tripf", (ir.Col("order_f"),))),),
        keep=("o_order_id",))


def _tp_t7(rng, cat, reg):  # logistic regression fraud
    lr = reg.register(builders.concat_ffnn("lrf", [12, 1, 1], [1],
                                           seed=int(rng.integers(1e6))))
    j = ir.Join(ir.Scan("financial_transactions"), ir.Scan("financial_account"),
                "senderID", "fa_customer_sk")
    return ir.Project(
        ir.Filter(j, pred=ir.Cmp(">", ir.Col("amount"),
                                 ir.Const(float(rng.integers(100, 3000))))),
        outputs=(("prob", ir.Call("lrf", (ir.Col("txn_f"), ir.Col("amount"),
                                          ir.Col("transaction_limit")))),),
        keep=("transactionID",))


def _tp_t8(rng, cat, reg):  # sales prediction per store
    f = reg.register(builders.ffnn("sales", _ffnn_dims(rng, 24),
                                   seed=int(rng.integers(1e6))))
    return ir.Project(ir.Scan("store"),
                      outputs=(("sales", ir.Call("sales", (ir.Col("store_f"),))),),
                      keep=("store",))


def _tp_t9(rng, cat, reg):  # customer segmentation (k-means)
    km = reg.register(builders.kmeans_assign("seg", int(rng.integers(3, 9)), 20,
                                             seed=int(rng.integers(1e6))))
    return ir.Project(
        ir.Filter(ir.Scan("customer"),
                  pred=ir.Cmp(">", ir.Col("c_birth_year"),
                              ir.Const(float(rng.integers(1950, 1995))))),
        outputs=(("cluster", ir.Call("seg", (ir.Col("customer_f"),))),),
        keep=("c_customer_sk",))


def _tp_t10(rng, cat, reg):  # customer satisfaction cross join
    f = reg.register(builders.concat_ffnn("sat", [20, 25],
                                          _ffnn_dims(rng, 45)[1:],
                                          seed=int(rng.integers(1e6))))
    return ir.Project(
        ir.Filter(ir.CrossJoin(ir.Scan("customer"), ir.Scan("product")),
                  pred=ir.Cmp("<", ir.Col("department"),
                              ir.Const(float(rng.integers(3, 10))))),
        outputs=(("sat", ir.Call("sat", (ir.Col("customer_f"),
                                         ir.Col("product_f")))),),
        keep=("c_customer_sk", "p_product_id"))


TEMPLATES = {
    1: ("ml", _ml_t1), 2: ("ml", _ml_t2), 3: ("ml", _ml_t3), 4: ("ml", _ml_t4),
    5: ("ml", _ml_t5), 6: ("ml", _ml_t6), 7: ("ml", _ml_t7), 8: ("ml", _ml_t8),
    9: ("ml", _ml_t9), 10: ("ml", _ml_t10),
    11: ("tp", _tp_t1), 12: ("tp", _tp_t2), 13: ("tp", _tp_t3),
    14: ("tp", _tp_t4), 15: ("tp", _tp_t5), 16: ("tp", _tp_t6),
    17: ("tp", _tp_t7), 18: ("tp", _tp_t8), 19: ("tp", _tp_t9),
    20: ("tp", _tp_t10),
}


def ood_split(seed: int = 42) -> Tuple[Tuple[int, ...], Tuple[int, ...]]:
    """14 in-distribution / 6 out-of-distribution template ids."""
    rng = np.random.default_rng(seed)
    ood = tuple(sorted(rng.choice(np.arange(1, 21), size=6, replace=False).tolist()))
    ind = tuple(t for t in range(1, 21) if t not in ood)
    return ind, ood


def sample_query(template_id: int, seed: int, scale: float = 1.0
                 ) -> Tuple[ir.Plan, ir.Catalog]:
    kind, fn = TEMPLATES[template_id]
    cat = catalog(kind, scale)
    rng = np.random.default_rng(seed)
    reg = Registry()
    root = fn(rng, cat, reg)
    return ir.Plan(root, reg), cat
