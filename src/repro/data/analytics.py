"""Synthetic Credit Card / Expedia / Flights analytics catalogs
(paper Sec. V-C4; dimension/row counts reduced for the CPU container but
keeping the workload structure: single scan / 3-way join / 4-way join,
4-6 predicate filters, scalers, tree classifiers)."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core.ir import Catalog
from repro.relational.table import Table


def build_creditcard(scale: float = 1.0, seed: int = 2):
    rng = np.random.default_rng(seed)
    n = max(256, int(2890 * scale))  # paper: 289k rows, 29 features
    cat = Catalog()
    cat.add("creditcard", Table.from_columns({
        "cc_id": jnp.arange(n, dtype=jnp.int32),
        "amount": jnp.asarray(rng.random(n) * 1e3, jnp.float32),
        "time": jnp.asarray(rng.random(n) * 24.0, jnp.float32),
        "cc_f": jnp.asarray(rng.standard_normal((n, 29)), jnp.float32),
    }))
    return cat


def build_expedia(scale: float = 1.0, seed: int = 3):
    rng = np.random.default_rng(seed)
    n_listing = max(128, int(790 * scale))  # paper: 79k rows, 3000 features
    n_hotel = max(32, int(100 * scale))
    n_search = max(32, int(120 * scale))
    cat = Catalog()
    cat.add("listings", Table.from_columns({
        "l_id": jnp.arange(n_listing, dtype=jnp.int32),
        "l_hotel_id": jnp.asarray(rng.integers(0, n_hotel, n_listing), jnp.int32),
        "l_search_id": jnp.asarray(rng.integers(0, n_search, n_listing), jnp.int32),
        "price": jnp.asarray(rng.random(n_listing) * 500, jnp.float32),
        "listing_f": jnp.asarray(rng.standard_normal((n_listing, 96)), jnp.float32),
    }))
    cat.add("hotel", Table.from_columns({
        "h_id": jnp.arange(n_hotel, dtype=jnp.int32),
        "stars": jnp.asarray(rng.integers(1, 6, n_hotel), jnp.float32),
        "hotel_f": jnp.asarray(rng.standard_normal((n_hotel, 80)), jnp.float32),
    }))
    cat.add("search", Table.from_columns({
        "s_id": jnp.arange(n_search, dtype=jnp.int32),
        "dest": jnp.asarray(rng.integers(0, 50, n_search), jnp.int32),
        "search_f": jnp.asarray(rng.standard_normal((n_search, 80)), jnp.float32),
    }))
    return cat


def build_flights(scale: float = 1.0, seed: int = 4):
    rng = np.random.default_rng(seed)
    n_routes = max(128, int(700 * scale))  # paper: 7k rows, 6000 features
    n_airlines = max(16, int(60 * scale))
    n_airports = max(32, int(120 * scale))
    cat = Catalog()
    cat.add("routes", Table.from_columns({
        "rt_id": jnp.arange(n_routes, dtype=jnp.int32),
        "rt_airline": jnp.asarray(rng.integers(0, n_airlines, n_routes), jnp.int32),
        "rt_src": jnp.asarray(rng.integers(0, n_airports, n_routes), jnp.int32),
        "rt_dst": jnp.asarray(rng.integers(0, n_airports, n_routes), jnp.int32),
        "stops": jnp.asarray(rng.integers(0, 3, n_routes), jnp.float32),
        "route_f": jnp.asarray(rng.standard_normal((n_routes, 128)), jnp.float32),
    }))
    cat.add("airlines", Table.from_columns({
        "al_id": jnp.arange(n_airlines, dtype=jnp.int32),
        "active": jnp.asarray(rng.integers(0, 2, n_airlines), jnp.int32),
        "airline_f": jnp.asarray(rng.standard_normal((n_airlines, 64)), jnp.float32),
    }))
    cat.add("src_airports", Table.from_columns({
        "sa_id": jnp.arange(n_airports, dtype=jnp.int32),
        "sa_country": jnp.asarray(rng.integers(0, 40, n_airports), jnp.int32),
        "sa_f": jnp.asarray(rng.standard_normal((n_airports, 64)), jnp.float32),
    }))
    cat.add("dst_airports", Table.from_columns({
        "da_id": jnp.arange(n_airports, dtype=jnp.int32),
        "da_country": jnp.asarray(rng.integers(0, 40, n_airports), jnp.int32),
        "da_f": jnp.asarray(rng.standard_normal((n_airports, 64)), jnp.float32),
    }))
    return cat
