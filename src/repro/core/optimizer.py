"""QueryEmbedder: bundles Model2Vec + Query2Vec + latency head with their
training loops (contrastive Task-1 over WL pairs, latency Task-2), and the
glue that turns them into the reusable MCTS's embed_fn / learned cost_fn.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import embedding as E
from repro.core import ir, wl
from repro.core.plan_cache import LRUCache
from repro.core.planner import analytic_cost_fn
from repro.train.optim import AdamW

EMBED_CACHE_SIZE = 4096  # embeddings are ~1.5KB; cap the store at a few MB


@dataclasses.dataclass
class QueryEmbedder:
    m2v: Dict
    q2v: Dict
    latency_q2v: Dict          # separate copy for Task 2 (two-model strategy)
    latency_head: Dict
    one_model: bool = False    # Sec. V-E baseline: joint training

    # LRU-bounded; mirrors the PlanCache interface (stats.hits/misses)
    _cache: LRUCache = dataclasses.field(
        default_factory=lambda: LRUCache(EMBED_CACHE_SIZE))

    @property
    def cache_stats(self):
        return self._cache.stats

    # -- embedding ----------------------------------------------------------
    def embed(self, plan: ir.Plan, catalog: ir.Catalog) -> np.ndarray:
        key = plan.signature()
        hit = self._cache.get(key)
        if hit is not None:
            return hit
        pf = E.featurize_plan(plan, catalog)
        emb = np.asarray(E.query2vec_apply(self.q2v, self.m2v,
                                           E.pf_to_arrays(pf)))
        self._cache.put(key, emb)
        return emb

    def embed_expr(self, graph) -> np.ndarray:
        feats, mask = E.featurize_graph(graph)
        return np.asarray(E.model2vec_apply(self.m2v, feats, mask))

    # -- latency prediction ---------------------------------------------------
    def predict_latency(self, plan: ir.Plan, catalog: ir.Catalog) -> float:
        pf = E.featurize_plan(plan, catalog)
        q2v = self.q2v if self.one_model else self.latency_q2v
        emb = E.query2vec_apply(q2v, self.m2v, E.pf_to_arrays(pf))
        log_lat = E.latency_apply(self.latency_head, emb)
        return float(jnp.exp(log_lat))

    def learned_cost_fn(self, catalog: ir.Catalog) -> Callable:
        return lambda plan: self.predict_latency(plan, catalog)


def init_embedder(seed: int = 0) -> QueryEmbedder:
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    return QueryEmbedder(m2v=E.init_model2vec(ks[0]),
                         q2v=E.init_query2vec(ks[1]),
                         latency_q2v=E.init_query2vec(ks[2]),
                         latency_head=E.init_latency_head(ks[3]))


# ===========================================================================
# pair mining (WL kernel) + training
# ===========================================================================

def mine_triples(items: Sequence, feats: Sequence, n_triples: int,
                 seed: int = 0) -> List[Tuple[int, int, int]]:
    """(anchor, positive, negative) index triples by WL cosine similarity."""
    rng = np.random.default_rng(seed)
    n = len(items)
    sims = np.zeros((n, n))
    for i in range(n):
        for j in range(i + 1, n):
            s = wl.wl_similarity(feats[i], feats[j])
            sims[i, j] = sims[j, i] = s
    triples = []
    for _ in range(n_triples):
        a = int(rng.integers(0, n))
        order = np.argsort(-sims[a])
        order = order[order != a]
        if len(order) < 2:
            continue
        pos = int(order[0])
        neg = int(order[int(rng.integers(max(1, len(order) // 2), len(order)))])
        triples.append((a, pos, neg))
    return triples


def train_model2vec(embedder: QueryEmbedder, graphs: Sequence,
                    steps: int = 200, batch: int = 16, seed: int = 0,
                    lr: float = 3e-4) -> Dict:
    """Task-1 contrastive training for Model2Vec over sampled model graphs."""
    feats = [wl.graph_wl(g) for g in graphs]
    triples = mine_triples(graphs, feats, n_triples=max(steps * batch, 256),
                           seed=seed)
    enc = [E.featurize_graph(g) for g in graphs]
    fa = jnp.stack([f for f, _ in enc])
    ma = jnp.stack([m for _, m in enc])
    opt = AdamW(lr=lr)
    params = embedder.m2v
    state = opt.init(params)

    @jax.jit
    def step(params, state, ai, pi, ni):
        def loss(p):
            ea = jax.vmap(lambda f, m: E.model2vec_apply(p, f, m))(fa[ai], ma[ai])
            ep = jax.vmap(lambda f, m: E.model2vec_apply(p, f, m))(fa[pi], ma[pi])
            en = jax.vmap(lambda f, m: E.model2vec_apply(p, f, m))(fa[ni], ma[ni])
            return E.contrastive_loss(ea, ep, en)
        l, g = jax.value_and_grad(loss)(params)
        params, state = opt.update(g, state, params)
        return params, state, l

    rng = np.random.default_rng(seed)
    hist = []
    for i in range(steps):
        idx = rng.integers(0, len(triples), batch)
        a, p, n = zip(*[triples[j] for j in idx])
        params, state, l = step(params, state, jnp.array(a), jnp.array(p),
                                jnp.array(n))
        hist.append(float(l))
    embedder.m2v = params
    return {"loss_first": hist[0], "loss_last": hist[-1]}


def _plan_batch_arrays(plans_feats: List[E.PlanFeatures]):
    return tuple(jnp.stack([getattr(pf, f.name) for pf in plans_feats])
                 for f in dataclasses.fields(E.PlanFeatures))


def train_query2vec(embedder: QueryEmbedder, plans, catalogs, steps: int = 200,
                    batch: int = 12, seed: int = 0, lr: float = 3e-4) -> Dict:
    """Task-1 contrastive training for Query2Vec over sampled queries."""
    feats = [wl.plan_wl(p.root, p.registry, phys=p.phys) for p in plans]
    triples = mine_triples(plans, feats, n_triples=max(steps * batch, 256),
                           seed=seed)
    pfs = [E.featurize_plan(p, c) for p, c in zip(plans, catalogs)]
    arrays = _plan_batch_arrays(pfs)
    opt = AdamW(lr=lr)
    params = embedder.q2v
    m2v = embedder.m2v
    state = opt.init(params)

    @jax.jit
    def step(params, state, ai, pi, ni):
        def emb(p, idx):
            sel = tuple(a[idx] for a in arrays)
            return jax.vmap(lambda *xs: E.query2vec_apply(p, m2v, xs))(*sel)

        def loss(p):
            return E.contrastive_loss(emb(p, ai), emb(p, pi), emb(p, ni))
        l, g = jax.value_and_grad(loss)(params)
        params, state = opt.update(g, state, params)
        return params, state, l

    rng = np.random.default_rng(seed)
    hist = []
    for i in range(steps):
        idx = rng.integers(0, len(triples), batch)
        a, p, n = zip(*[triples[j] for j in idx])
        params, state, l = step(params, state, jnp.array(a), jnp.array(p),
                                jnp.array(n))
        hist.append(float(l))
    embedder.q2v = params
    embedder._cache.clear()
    return {"loss_first": hist[0], "loss_last": hist[-1]}


def train_latency(embedder: QueryEmbedder, plans, catalogs,
                  latencies: Sequence[float], steps: int = 300,
                  batch: int = 16, seed: int = 0, lr: float = 3e-4,
                  one_model: bool = False) -> Dict:
    """Task-2: latency head (4-layer FFNN, MSE on log latency).

    Two-model strategy (default): a separate Query2Vec copy (initialized from
    the contrastively-trained one) is fine-tuned jointly with the head.
    One-model: the shared Query2Vec is trained jointly (Sec. V-E baseline).
    """
    pfs = [E.featurize_plan(p, c) for p, c in zip(plans, catalogs)]
    arrays = _plan_batch_arrays(pfs)
    y = jnp.log(jnp.asarray(latencies) + 1e-9)
    if not one_model:
        embedder.latency_q2v = jax.tree.map(jnp.copy, embedder.q2v)
    q2v = embedder.q2v if one_model else embedder.latency_q2v
    m2v = embedder.m2v
    opt = AdamW(lr=lr)
    params = {"q2v": q2v, "head": embedder.latency_head}
    state = opt.init(params)

    @jax.jit
    def step(params, state, idx):
        def loss(p):
            sel = tuple(a[idx] for a in arrays)
            emb = jax.vmap(lambda *xs: E.query2vec_apply(p["q2v"], m2v, xs))(*sel)
            pred = E.latency_apply(p["head"], emb)
            return E.latency_loss(pred, y[idx])
        l, g = jax.value_and_grad(loss)(params)
        params, state = opt.update(g, state, params)
        return params, state, l

    rng = np.random.default_rng(seed)
    hist = []
    for i in range(steps):
        idx = jnp.asarray(rng.integers(0, len(plans), batch))
        params, state, l = step(params, state, idx)
        hist.append(float(l))
    if one_model:
        embedder.q2v = params["q2v"]
        embedder.one_model = True
    else:
        embedder.latency_q2v = params["q2v"]
    embedder.latency_head = params["head"]
    embedder._cache.clear()
    return {"loss_first": hist[0], "loss_last": hist[-1]}


def q_error(pred: np.ndarray, actual: np.ndarray) -> np.ndarray:
    pred = np.maximum(pred, 1e-12)
    actual = np.maximum(actual, 1e-12)
    return np.maximum(pred / actual, actual / pred)
