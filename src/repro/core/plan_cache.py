"""Compiled-plan cache: skip lowering AND jax tracing for repeated queries.

``PlanCache.get_or_compile(plan, catalog)`` returns a jitted callable
``run(tables) -> Table`` keyed by the plan's structural+physical signature
plus the catalog's schema signature (table/column names, dtypes, static
shapes — anything that would force a retrace). Two structurally identical
plans over same-shaped catalogs share one compiled executable; fresh table
*contents* flow through as arguments, so parameterized / repeated query
traffic pays tracing exactly once. Referenced ML functions contribute their
name + architecture (atom kinds, parameter shapes/dtypes) to the key; weight
*values* are assumed stable per name (model-registry contract) — an in-place
weight update that keeps name and shapes needs a fresh name or cache.

Lowering inside the cache is *cost-driven* (``core.costed_lowering``
against the cache's ``DeviceProfile``), and the chosen realization vector
is part of ``key()`` (the ``#cl=...`` suffix). ``recalibrate(profile)`` —
the serving feedback loop's entry point — bumps ``profile_epoch``, which
invalidates the per-signature lowering memo: a recalibrated profile that
changes a lowering decision selects a *different* executable under a new
key instead of aliasing the stale one (equal decisions keep sharing the
old entry, which is exactly right — every realization computes the same
result, only the predicted latency moved).

``get_or_compile_batched(plan, catalog, batch_size)`` is the serving tier's
entry point (repro.serving): same key plus a ``#vmap=B`` suffix, and the
compiled executable is one ``jax.vmap``ped dispatch over B same-signature
table pytrees stacked on a leading axis — N structurally identical in-flight
queries pay one dispatch instead of N.

``get_or_compile_sharded(plan, catalog, batch_size, mesh)`` realizes the
same micro-batch on a multi-device mesh (``backend="sharded"``): the stacked
batch axis is ``shard_map``ped over the mesh's data axis, with automatic
fallback to the vmapped single-device program when the batch doesn't divide
the device count or only one device exists.

``get_or_compile_partitioned(plan, catalog, mesh)`` is the intra-query
counterpart for a *single oversized* query: lowering opens per-node
``PartSpec`` candidates (operators partitioned over the mesh's data axis,
explicit ``PRepartition`` collectives) under the profile's per-device
``memory_budget``, and the chosen plan runs inside ``shard_map`` with
replicated inputs/outputs. ``key(plan, catalog, mesh=...)`` exposes the
matching key (the ``pt*`` decision tokens are the PartSpec vector).

``LRUCache`` + ``CacheStats`` are the shared bounded-cache machinery (also
used to bound the QueryEmbedder's embedding cache).
"""
from __future__ import annotations

import dataclasses
import weakref
from collections import OrderedDict
from typing import Any, Callable, Dict, Hashable, Optional

import jax
import jax.numpy as jnp

from repro.core import costed_lowering, ir
from repro.core import physical as ph
from repro.core.cost import DeviceProfile
from repro.relational.table import Table


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    evictions: int = 0

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def as_dict(self) -> Dict[str, float]:
        return {"hits": self.hits, "misses": self.misses,
                "evictions": self.evictions, "hit_rate": self.hit_rate}


class LRUCache:
    """Size-capped mapping with LRU eviction and hit/miss accounting."""

    def __init__(self, maxsize: int = 128):
        self.maxsize = max(1, int(maxsize))
        self._data: "OrderedDict[Hashable, Any]" = OrderedDict()
        self.stats = CacheStats()

    def get(self, key: Hashable, default=None):
        if key in self._data:
            self._data.move_to_end(key)
            self.stats.hits += 1
            return self._data[key]
        self.stats.misses += 1
        return default

    def put(self, key: Hashable, value) -> None:
        if key in self._data:
            self._data.move_to_end(key)
        self._data[key] = value
        while len(self._data) > self.maxsize:
            self._data.popitem(last=False)
            self.stats.evictions += 1

    def __len__(self) -> int:
        return len(self._data)

    def __contains__(self, key: Hashable) -> bool:
        return key in self._data

    def clear(self) -> None:
        self._data.clear()


def scan_table_names(plan: ir.Plan) -> tuple:
    """The catalog tables a plan actually reads, sorted."""
    return tuple(sorted({n.table for n in ir.walk(plan.root)
                         if isinstance(n, ir.Scan)}))


def schema_signature(catalog: ir.Catalog,
                     names: Optional[tuple] = None) -> str:
    """Static catalog shape: anything that changes the traced program.

    ``names`` restricts the signature to the given tables — ``PlanCache.key``
    passes the plan's scanned tables, so catalog entries a plan never reads
    cannot force a false cache miss (and a retrace) when they appear, change
    shape, or disappear. ``None`` signs the whole catalog.
    """
    if names is None:
        names = sorted(catalog.tables)
    parts = []
    for name in names:
        t = catalog.tables[name]
        cols = ",".join(f"{c}:{t.columns[c].dtype}:{t.columns[c].shape}"
                        for c in sorted(t.columns))
        parts.append(f"{name}[{t.capacity}]({cols})")
    return ";".join(parts)


def _plan_fn_names(plan: ir.Plan):
    names = set()

    def from_expr(e: ir.Expr):
        if isinstance(e, ir.Call):
            names.add(e.fn)
        for c in e.children():
            from_expr(c)

    for node in ir.walk(plan.root):
        if isinstance(node, ir.Filter):
            from_expr(node.pred)
        elif isinstance(node, ir.Project):
            for _, e in node.outputs:
                from_expr(e)
        elif isinstance(node, (ir.BlockedMatmul, ir.ForestRelational)):
            names.add(node.fn)
    return sorted(names)


def registry_signature(plan: ir.Plan) -> str:
    """Architecture signature of every ML function the plan references:
    atom kinds + parameter shapes/dtypes (cheap — no weight hashing). Guards
    the name-identity assumption against same-named functions with different
    architectures; a weight update that keeps name AND shapes must bump the
    function name (or use a fresh cache) to invalidate."""
    parts = []
    for name in _plan_fn_names(plan):
        try:
            fn = plan.registry.get(name)
        except KeyError:
            parts.append(f"{name}:?")
            continue
        if fn.graph is None:
            parts.append(f"{name}:opaque")
            continue
        atoms = []
        for n in fn.graph.nodes:
            ps = ",".join(
                f"{k}={getattr(v, 'shape', v)}:{getattr(v, 'dtype', '')}"
                for k, v in sorted(n.atom.params.items()))
            atoms.append(f"{n.atom.kind}({ps})@{n.atom.backend}")
        parts.append(f"{name}:{'|'.join(atoms)}")
    return ";".join(parts)


class PlanCache:
    """Signature-keyed cache of compiled (jitted) plan executables."""

    def __init__(self, maxsize: int = 64,
                 profile: Optional[DeviceProfile] = None):
        self._cache = LRUCache(maxsize)
        self.traces = 0  # times jax actually (re)traced a cached executable
        self._profile = profile  # lazily detected; see profile property
        self.profile_epoch = 0   # bumped by recalibrate()
        # per-(signature, backend, epoch) costed-lowering results: warm
        # dispatches pay one LRU lookup, not a candidate enumeration
        self._lowered = LRUCache(256)

    @property
    def stats(self) -> CacheStats:
        return self._cache.stats

    @property
    def profile(self) -> DeviceProfile:
        """The device profile lowering decisions are costed against."""
        if self._profile is None:
            self._profile = DeviceProfile.detect()
        return self._profile

    def recalibrate(self, profile: DeviceProfile) -> None:
        """Install a (feedback-calibrated) profile. Bumping the epoch
        re-derives lowering decisions on the next dispatch of every
        signature; signatures whose decisions change get fresh cache keys
        (no stale-executable aliasing), unchanged ones keep their entry."""
        self._profile = profile
        self.profile_epoch += 1

    def base_key(self, plan: ir.Plan, catalog: ir.Catalog) -> str:
        # sign only the tables the plan scans: the traced program never sees
        # the rest of the catalog, so an unrelated table must not over-key
        # the cache into a false miss (see schema_signature)
        return (plan.signature()
                + "@" + schema_signature(catalog, scan_table_names(plan))
                + "@" + registry_signature(plan))

    def key(self, plan: ir.Plan, catalog: ir.Catalog, *, mesh=None,
            backend: Optional[str] = None) -> str:
        """Full executable key: base signature + the realization vector the
        costed lowering chose under the cache's current profile.

        With ``mesh`` given (and more than one device on it), the key is
        the *partitioned* realization's: ``#be=part#mesh=...`` plus the
        decision vector of the PartSpec-aware lowering — the ``pt*`` site
        tokens in the ``#cl=`` suffix ARE the PartSpec vector, so two
        queries only share a partitioned executable when every node's
        partitioning decision agrees. The serving tier keys oversized
        single queries this way (``QueryServer.submit``); ``backend`` is
        the caller's node-level kernel override, mirrored into the
        partitioned lowering so the key matches what
        ``get_or_compile_partitioned`` will compile."""
        from repro.core import mesh as mesh_util

        base = self.base_key(plan, catalog)
        ways = mesh_util.batch_ways(mesh) if mesh is not None else 1
        if ways > 1:
            base = f"{base}#be=part#mesh={mesh_util.mesh_signature(mesh)}"
            if backend is not None:
                base = f"{base}#nbe={backend}"
            low = self._lowered_for(plan, catalog, base, backend, ways=ways)
        else:
            low = self._lowered_for(plan, catalog, base, None)
        return base + "#cl=" + low.signature

    def _lowered_for(self, plan: ir.Plan, catalog: ir.Catalog,
                     keyed: str, backend: Optional[str], ways: int = 1
                     ) -> costed_lowering.Lowered:
        """Costed-lowering result for ``plan``, memoized per (signature,
        backend, profile epoch, *catalog object*) — ``keyed`` must already
        include the ``#be=`` suffix when ``backend`` is set, and the
        ``#be=part#mesh=`` suffix when ``ways > 1``.

        Catalog identity matters because compaction decisions are sized
        from the catalog's *data* (exact row counts), which the schema-only
        signature cannot see: a different same-schema catalog re-derives
        its own decisions (and, via the ``#cl=`` key suffix, its own
        executable when the counts differ enough to change a capacity).
        The weakref guards id reuse by a freed catalog."""
        mk = (keyed, self.profile_epoch, id(catalog))
        hit = self._lowered.get(mk)
        if hit is not None and hit[0]() is catalog:
            return hit[1]
        low = costed_lowering.lower_costed(plan, catalog,
                                           profile=self.profile,
                                           backend=backend, ways=ways)
        self._lowered.put(mk, (weakref.ref(catalog), low))
        return low

    @staticmethod
    def _strip_cl(key: str) -> str:
        """Drop a stale ``#cl=`` decision suffix — and any ``#be=``
        realization suffix preceding it — from a caller-memoized key (both
        are re-derived against the current profile epoch / entry point)."""
        return key.split("#be=", 1)[0].split("#cl=", 1)[0]

    def get_or_compile(self, plan: ir.Plan, catalog: ir.Catalog,
                       *, backend: Optional[str] = None,
                       cache_key: Optional[str] = None
                       ) -> Callable[[Dict[str, Table]], Table]:
        """``cache_key`` lets hot callers (the serving tier memoizes it at
        admission) skip the signature walk on warm dispatches; it must equal
        ``self.key(plan, catalog)``."""
        base = self._strip_cl(cache_key if cache_key is not None
                              else self.base_key(plan, catalog))
        if backend is not None:
            base = f"{base}#be={backend}"
        low = self._lowered_for(plan, catalog, base, backend)
        key = base + "#cl=" + low.signature
        fn = self._cache.get(key)
        if fn is None:
            pplan = low.plan
            names = scan_table_names(plan)

            def traced(tables: Dict[str, Table]) -> Table:
                self.traces += 1  # python side effect: runs only while tracing
                return ph.run(pplan, tables)

            jfn = jax.jit(traced)

            def fn(tables: Dict[str, Table]) -> Table:
                # normalize to the scanned tables only: full-catalog and
                # restricted callers share one traced structure (and one
                # trace), and unused tables never cross the jit boundary
                return jfn({k: tables[k] for k in names})

            self._cache.put(key, fn)
        return fn

    def get_or_compile_batched(self, plan: ir.Plan, catalog: ir.Catalog,
                               batch_size: int, *,
                               backend: Optional[str] = None,
                               cache_key: Optional[str] = None):
        """One vmapped dispatch over ``batch_size`` same-signature queries.

        Returns ``run(tables_seq) -> tuple[Table, ...]`` taking a sequence
        of ``batch_size`` same-schema ``{name: Table}`` dicts (fresh
        contents per query — the signature grouping guarantees the shapes
        agree). Stacking onto the leading batch axis, the vmapped plan
        body, and the per-query unstacking are all one jitted XLA program:
        a micro-batch costs a single dispatch, which is the whole point
        (per-dispatch overhead dominates repeated small queries). The batch
        size is part of the cache key — the serving scheduler's admission
        policy bounds how many distinct sizes traffic can create. All
        physical operators are mask/capacity-based with static shapes,
        which is what makes the plan body vmap-safe
        (tests/test_serving_batched.py proves batched == sequential on all
        12 workloads).
        """
        if batch_size < 1:
            raise ValueError(f"batch_size must be >= 1, got {batch_size}")
        base = self._strip_cl(cache_key if cache_key is not None
                              else self.base_key(plan, catalog))
        if backend is not None:
            base = f"{base}#be={backend}"
        low = self._lowered_for(plan, catalog, base, backend)
        key = base + "#cl=" + low.signature + f"#vmap={batch_size}"
        return self._get_or_compile_stacked(key, low.plan, plan, catalog,
                                            batch_size, kind="batched")

    def _get_or_compile_stacked(self, key: str, pplan, plan: ir.Plan,
                                catalog: ir.Catalog, batch_size: int, *,
                                kind: str,
                                wrap: Optional[Callable] = None):
        """Shared body of the batched/sharded entries: stack ``batch_size``
        same-schema table dicts on a leading axis, run the vmapped plan body
        (optionally transformed by ``wrap``, e.g. shard_map over a mesh),
        and unstack per-query results — all one jitted program under
        ``key``. Keeping one implementation keeps trace accounting, payload
        restriction to scanned tables, and the batch-size guard identical
        across realizations."""
        fn = self._cache.get(key)
        if fn is None:
            names = scan_table_names(plan)

            def batch_body(stacked):
                return jax.vmap(lambda tables: ph.run(pplan, tables))(stacked)

            body = wrap(batch_body) if wrap is not None else batch_body

            def traced(tables_seq):
                self.traces += 1  # python side effect: runs only while tracing
                out = body(stack_tables(list(tables_seq)))
                return tuple(unstack_table(out, i)
                             for i in range(batch_size))

            jfn = jax.jit(traced)

            def fn(tables_seq):
                if len(tables_seq) != batch_size:
                    raise ValueError(
                        f"{kind} executable compiled for batch_size="
                        f"{batch_size}, got {len(tables_seq)} table dicts")
                return jfn(tuple({k: t[k] for k in names}
                                 for t in tables_seq))

            self._cache.put(key, fn)
        return fn

    def get_or_compile_sharded(self, plan: ir.Plan, catalog: ir.Catalog,
                               batch_size: int, mesh, *,
                               cache_key: Optional[str] = None):
        """Multi-device variant of ``get_or_compile_batched``: the stacked
        batch axis of the micro-batch is ``shard_map``ped over ``mesh``'s
        data axis, so each device runs the vmapped plan body on its
        ``batch_size / ways`` slice. The batch axis is embarrassingly
        parallel (no cross-query communication), which is why this needs no
        operator changes — weights and other closed-over arrays replicate.

        The realization is first-class in the cache key
        (``#be=sharded#vmap=B#mesh=...``), keeping it distinct from the
        single-device vmapped executable of the same plan and batch size.
        Ineligible calls — a single-device mesh, or a ``batch_size`` the
        device count doesn't divide (``core.mesh.can_shard``, the same
        divisibility-fitting policy as ``models.sharding``) — fall back to
        the plain batched executable under *its* key, so fallback traffic
        shares the existing entry instead of compiling a duplicate.
        """
        from repro.core import mesh as mesh_util

        if batch_size < 1:
            raise ValueError(f"batch_size must be >= 1, got {batch_size}")
        if not mesh_util.can_shard(mesh, batch_size):
            return self.get_or_compile_batched(plan, catalog, batch_size,
                                               cache_key=cache_key)
        base = self._strip_cl(cache_key if cache_key is not None
                              else self.base_key(plan, catalog))
        base = f"{base}#be=sharded"
        low = self._lowered_for(plan, catalog, base, "sharded")
        key = (base + "#cl=" + low.signature + f"#vmap={batch_size}"
               + f"#mesh={mesh_util.mesh_signature(mesh)}")
        return self._get_or_compile_stacked(
            key, low.plan, plan, catalog, batch_size, kind="sharded",
            wrap=lambda body: mesh_util.shard_batch(body, mesh))

    def get_or_compile_partitioned(self, plan: ir.Plan, catalog: ir.Catalog,
                                   mesh, *, backend: Optional[str] = None,
                                   cache_key: Optional[str] = None):
        """One *intra-query-sharded* executable for a single oversized
        query: lowering opens per-node ``PartSpec`` candidates
        (``ways = batch_ways(mesh)``), rejects candidates whose per-device
        ``phys_peak_memory`` busts the profile's ``memory_budget``, and the
        chosen plan — explicit ``PRepartition`` collectives included — runs
        inside ``shard_map`` over the mesh's data axis with replicated
        inputs/outputs (``core.mesh.shard_replicated``). Unlike
        ``get_or_compile_sharded`` there is no batch axis: the *operators*
        are partitioned (PCrossJoin by left rows, PJoin by probe rows or
        hash bucket, pipelines/ML by row block), which is what lets one
        query larger than a device use the whole mesh.

        Returns ``run(tables) -> Table`` like ``get_or_compile``. The
        realization is first-class in the key
        (``#be=part#mesh=...#cl=...`` — the ``pt*`` decision tokens are
        the PartSpec vector). ``backend`` constrains every node's *kernel*
        realization exactly as in ``get_or_compile`` (partitioning is a
        distribution choice, orthogonal to the caller's kernel choice).
        Single-device meshes, and lowerings that decide partitioning does
        not pay (every PartSpec replicated), fall back to the plain
        executable under *its* key — no duplicate compilation."""
        from repro.core import mesh as mesh_util

        ways = mesh_util.batch_ways(mesh) if mesh is not None else 1
        if ways <= 1:
            return self.get_or_compile(plan, catalog, backend=backend,
                                       cache_key=cache_key)
        base = self._strip_cl(cache_key if cache_key is not None
                              else self.base_key(plan, catalog))
        base = f"{base}#be=part#mesh={mesh_util.mesh_signature(mesh)}"
        if backend is not None:
            base = f"{base}#nbe={backend}"
        low = self._lowered_for(plan, catalog, base, backend, ways=ways)
        if low.plan.ways <= 1:
            # the oracle kept every node replicated: the partitioned
            # program would be the plain one run redundantly on every
            # device — share the plain executable instead
            return self.get_or_compile(plan, catalog, backend=backend)
        key = base + "#cl=" + low.signature
        fn = self._cache.get(key)
        if fn is None:
            pplan = low.plan
            names = scan_table_names(plan)

            def traced(tables: Dict[str, Table]) -> Table:
                self.traces += 1  # python side effect: runs only while tracing
                return ph.run(pplan, tables, axis=mesh_util.DATA_AXIS)

            jfn = jax.jit(mesh_util.shard_replicated(traced, mesh))

            def fn(tables: Dict[str, Table]) -> Table:
                return jfn({k: tables[k] for k in names})

            self._cache.put(key, fn)
        return fn

    def __call__(self, plan: ir.Plan, catalog: ir.Catalog) -> Table:
        """Convenience: compile-or-reuse, then execute on catalog tables."""
        return self.get_or_compile(plan, catalog)(dict(catalog.tables))


def stack_tables(tables_list) -> Dict[str, Table]:
    """Stack N same-schema ``{name: Table}`` dicts on a new leading axis.

    All dicts must share one schema signature (same table names, column
    names, dtypes, capacities) — exactly the property the serving tier's
    signature grouping guarantees.
    """
    if not tables_list:
        raise ValueError("stack_tables needs at least one table dict")
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *tables_list)


def unstack_table(batched: Table, i: int) -> Table:
    """Slice query ``i``'s result out of a batched executable's output."""
    return jax.tree_util.tree_map(lambda x: x[i], batched)


GLOBAL_PLAN_CACHE = PlanCache()
