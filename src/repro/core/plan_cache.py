"""Compiled-plan cache: skip lowering AND jax tracing for repeated queries.

``PlanCache.get_or_compile(plan, catalog)`` returns a jitted callable
``run(tables) -> Table`` keyed by the plan's structural+physical signature
plus the catalog's schema signature (table/column names, dtypes, static
shapes — anything that would force a retrace). Two structurally identical
plans over same-shaped catalogs share one compiled executable; fresh table
*contents* flow through as arguments, so parameterized / repeated query
traffic pays tracing exactly once. Referenced ML functions contribute their
name + architecture (atom kinds, parameter shapes/dtypes) to the key; weight
*values* are assumed stable per name (model-registry contract) — an in-place
weight update that keeps name and shapes needs a fresh name or cache.

``LRUCache`` + ``CacheStats`` are the shared bounded-cache machinery (also
used to bound the QueryEmbedder's embedding cache).
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Any, Callable, Dict, Hashable, Optional

import jax

from repro.core import ir
from repro.core.lowering import lower
from repro.core import physical as ph
from repro.relational.table import Table


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    evictions: int = 0

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def as_dict(self) -> Dict[str, float]:
        return {"hits": self.hits, "misses": self.misses,
                "evictions": self.evictions, "hit_rate": self.hit_rate}


class LRUCache:
    """Size-capped mapping with LRU eviction and hit/miss accounting."""

    def __init__(self, maxsize: int = 128):
        self.maxsize = max(1, int(maxsize))
        self._data: "OrderedDict[Hashable, Any]" = OrderedDict()
        self.stats = CacheStats()

    def get(self, key: Hashable, default=None):
        if key in self._data:
            self._data.move_to_end(key)
            self.stats.hits += 1
            return self._data[key]
        self.stats.misses += 1
        return default

    def put(self, key: Hashable, value) -> None:
        if key in self._data:
            self._data.move_to_end(key)
        self._data[key] = value
        while len(self._data) > self.maxsize:
            self._data.popitem(last=False)
            self.stats.evictions += 1

    def __len__(self) -> int:
        return len(self._data)

    def __contains__(self, key: Hashable) -> bool:
        return key in self._data

    def clear(self) -> None:
        self._data.clear()


def schema_signature(catalog: ir.Catalog) -> str:
    """Static catalog shape: anything that changes the traced program."""
    parts = []
    for name in sorted(catalog.tables):
        t = catalog.tables[name]
        cols = ",".join(f"{c}:{t.columns[c].dtype}:{t.columns[c].shape}"
                        for c in sorted(t.columns))
        parts.append(f"{name}[{t.capacity}]({cols})")
    return ";".join(parts)


def _plan_fn_names(plan: ir.Plan):
    names = set()

    def from_expr(e: ir.Expr):
        if isinstance(e, ir.Call):
            names.add(e.fn)
        for c in e.children():
            from_expr(c)

    for node in ir.walk(plan.root):
        if isinstance(node, ir.Filter):
            from_expr(node.pred)
        elif isinstance(node, ir.Project):
            for _, e in node.outputs:
                from_expr(e)
        elif isinstance(node, (ir.BlockedMatmul, ir.ForestRelational)):
            names.add(node.fn)
    return sorted(names)


def registry_signature(plan: ir.Plan) -> str:
    """Architecture signature of every ML function the plan references:
    atom kinds + parameter shapes/dtypes (cheap — no weight hashing). Guards
    the name-identity assumption against same-named functions with different
    architectures; a weight update that keeps name AND shapes must bump the
    function name (or use a fresh cache) to invalidate."""
    parts = []
    for name in _plan_fn_names(plan):
        try:
            fn = plan.registry.get(name)
        except KeyError:
            parts.append(f"{name}:?")
            continue
        if fn.graph is None:
            parts.append(f"{name}:opaque")
            continue
        atoms = []
        for n in fn.graph.nodes:
            ps = ",".join(
                f"{k}={getattr(v, 'shape', v)}:{getattr(v, 'dtype', '')}"
                for k, v in sorted(n.atom.params.items()))
            atoms.append(f"{n.atom.kind}({ps})@{n.atom.backend}")
        parts.append(f"{name}:{'|'.join(atoms)}")
    return ";".join(parts)


class PlanCache:
    """Signature-keyed cache of compiled (jitted) plan executables."""

    def __init__(self, maxsize: int = 64):
        self._cache = LRUCache(maxsize)
        self.traces = 0  # times jax actually (re)traced a cached executable

    @property
    def stats(self) -> CacheStats:
        return self._cache.stats

    def key(self, plan: ir.Plan, catalog: ir.Catalog) -> str:
        return (plan.signature() + "@" + schema_signature(catalog)
                + "@" + registry_signature(plan))

    def get_or_compile(self, plan: ir.Plan, catalog: ir.Catalog,
                       *, backend: Optional[str] = None
                       ) -> Callable[[Dict[str, Table]], Table]:
        key = self.key(plan, catalog)
        if backend is not None:
            key = f"{key}#be={backend}"
        fn = self._cache.get(key)
        if fn is None:
            pplan = lower(plan, catalog, backend=backend)

            def traced(tables: Dict[str, Table]) -> Table:
                self.traces += 1  # python side effect: runs only while tracing
                return ph.run(pplan, tables)

            fn = jax.jit(traced)
            self._cache.put(key, fn)
        return fn

    def __call__(self, plan: ir.Plan, catalog: ir.Catalog) -> Table:
        """Convenience: compile-or-reuse, then execute on catalog tables."""
        return self.get_or_compile(plan, catalog)(dict(catalog.tables))


GLOBAL_PLAN_CACHE = PlanCache()
