"""Vanilla + Reusable MCTS query optimizers (paper Sec. IV, Alg. 1-5, 10).

States are query plans; in the reusable optimizer states are *embeddings*
(Query2Vec vectors) held in a global node store shared across queries, and
actions are *configurable* co-optimization rules: selecting an action picks
the rule, then the rule is configured (heuristic narrowing + cost-model
scoring of candidate configs) for the concrete query — Sec. IV-B2.
"""
from __future__ import annotations

import dataclasses
import math
import random
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core import cost, ir
from repro.core.rules import ALL_RULES
from repro.core.rules.base import RuleConfig

ACTION_SPACE = ["R1-1", "R1-2", "R1-3", "R1-4-merge", "R1-4-split", "compact",
                "R2-1", "R2-3", "R3-1", "R3-2", "R3-3", "R4-1-split",
                "R4-1-fuse", "R4-1-unfuse", "R4-2", "R4-4"]

CostFn = Callable[[ir.Plan], float]


def _heuristic_narrow(action: str, plan: ir.Plan, cfgs: List[RuleConfig],
                      topk: int) -> List[RuleConfig]:
    """Paper: 'we first use heuristics, if available, to narrow the
    candidates, e.g. the matMul functions involving the top-k largest
    tensors'."""
    if action == "R3-1":
        def wbytes(c):
            fn = plan.registry.get(c.get("fn"))
            return -fn.graph.nodes[c.get("idx")].atom.param_bytes()
        cfgs = sorted(cfgs, key=wbytes)
    elif action == "compact":
        cfgs = sorted(cfgs, key=lambda c: c.get("capacity"))
    return cfgs[:topk]


def configure_action(plan: ir.Plan, catalog: ir.Catalog, action: str,
                     cost_fn: CostFn, topk: int = 4
                     ) -> Optional[Tuple[ir.Plan, RuleConfig]]:
    """Pick the best configuration of `action` for this plan (or None if the
    rule is inapplicable)."""
    rule = ALL_RULES[action]
    cfgs = rule.configs(plan, catalog)
    if not cfgs:
        return None
    cfgs = _heuristic_narrow(action, plan, cfgs, topk)
    best, best_cost = None, float("inf")
    for cfg in cfgs:
        try:
            cand = rule.apply(plan, catalog, cfg)
        except Exception:
            continue
        c = cost_fn(cand)
        if c < best_cost:
            best, best_cost, best_cfg = cand, c, cfg
    if best is None:
        return None
    return best, best_cfg


# ===========================================================================
# Vanilla MCTS (Alg. 1-4 + 10): fresh tree per query
# ===========================================================================

@dataclasses.dataclass
class _VNode:
    plan: ir.Plan
    cost: float
    parent: Optional["_VNode"] = None
    action: Optional[str] = None
    depth: int = 0
    n: int = 0
    r: float = 0.0
    children: Dict[str, "_VNode"] = dataclasses.field(default_factory=dict)
    untried: Optional[List[str]] = None
    dead: set = dataclasses.field(default_factory=set)

    def terminal(self, max_depth):
        return self.depth >= max_depth or (
            self.untried is not None and not self.untried and not self.children)


def _select_ucb(node: _VNode, c: float) -> _VNode:
    """Alg. 1: argmax r_i/n_i + c*sqrt(ln N / n_i)."""
    best, best_v = None, -float("inf")
    for ch in node.children.values():
        v = ch.r / max(ch.n, 1) + c * math.sqrt(math.log(max(node.n, 1)) / max(ch.n, 1))
        if v > best_v:
            best, best_v = ch, v
    return best


class VanillaMCTS:
    def __init__(self, catalog: ir.Catalog, cost_fn: Optional[CostFn] = None,
                 iterations: int = 40,
                 c: float = 0.7, max_depth: int = 6, rollout_depth: int = 3,
                 seed: int = 0, actions: Optional[List[str]] = None):
        self.catalog = catalog
        # default reward oracle: the shared plan_cost entry point (the same
        # oracle costed lowering scores its physical candidates with)
        self.cost_fn = cost_fn or (lambda p: cost.plan_cost(p, catalog))
        self.iterations = iterations
        self.c = c
        self.max_depth = max_depth
        self.rollout_depth = rollout_depth
        self.rng = random.Random(seed)
        self.actions = actions or ACTION_SPACE

    def _expandable(self, node: _VNode) -> List[str]:
        if node.untried is None:
            node.untried = [a for a in self.actions if a not in node.dead]
        return node.untried

    def _take(self, node: _VNode, action: str) -> Optional[_VNode]:
        res = configure_action(node.plan, self.catalog, action, self.cost_fn)
        if res is None:
            node.dead.add(action)
            return None
        plan2, _ = res
        child = _VNode(plan=plan2, cost=self.cost_fn(plan2), parent=node,
                       action=action, depth=node.depth + 1)
        node.children[action] = child
        return child

    def _rollout(self, node: _VNode) -> _VNode:
        """Alg. 3: random actions to a terminal (or budget)."""
        cur = node
        for _ in range(self.rollout_depth):
            acts = list(self.actions)
            self.rng.shuffle(acts)
            nxt = None
            for a in acts:
                if a in cur.dead or a in cur.children:
                    continue
                nxt = self._take(cur, a)
                if nxt is not None:
                    break
            if nxt is None:
                break
            cur = nxt
        return cur

    def optimize(self, plan: ir.Plan) -> Tuple[ir.Plan, Dict]:
        root = _VNode(plan=plan, cost=self.cost_fn(plan))
        best_plan, best_cost = plan, root.cost
        for _ in range(self.iterations):
            node = root
            # selection: descend fully-expanded nodes (Alg. 10)
            while not node.terminal(self.max_depth):
                untried = self._expandable(node)
                if untried:
                    a = self.rng.choice(untried)
                    untried.remove(a)
                    child = self._take(node, a)
                    if child is None:
                        continue
                    node = self._rollout(child)
                    break
                sel = _select_ucb(node, self.c)
                if sel is None:
                    break
                node = sel
            # reward (paper: cost_root - cost_T, normalized here)
            reward = (root.cost - node.cost) / max(root.cost, 1e-12)
            if node.cost < best_cost:
                best_plan, best_cost = node.plan, node.cost
            # backpropagate (Alg. 4)
            cur = node
            while cur is not None:
                cur.n += 1
                cur.r += reward
                cur = cur.parent
        return best_plan, {"root_cost": root.cost, "best_cost": best_cost,
                           "speedup": root.cost / max(best_cost, 1e-12)}


# ===========================================================================
# Reusable MCTS (Alg. 5): embedding-keyed global node store
# ===========================================================================

@dataclasses.dataclass
class _RNode:
    nid: int
    embed: np.ndarray                      # normalized 393-d state embedding
    n: int = 0
    r: float = 0.0
    children: Dict[str, int] = dataclasses.field(default_factory=dict)
    dead: set = dataclasses.field(default_factory=set)
    untried: Optional[List[str]] = None
    # best known rule chain from this state (as a search root) + the
    # root-relative speedup it achieved: the warm-start replay sketch
    best_seq: Tuple[str, ...] = ()
    best_gain: float = 1.0

    def storage_bytes(self) -> int:
        return (self.embed.nbytes + 64 + 16 * len(self.children)
                + 8 * len(self.best_seq))


class NodeIndex:
    """Exact cosine NN index over node embeddings (the paper uses Faiss;
    index sizes here are small enough for the exact search)."""

    def __init__(self):
        self._embs: List[np.ndarray] = []
        self._ids: List[int] = []
        self._mat: Optional[np.ndarray] = None

    def add(self, nid: int, emb: np.ndarray):
        self._embs.append(emb.astype(np.float32))
        self._ids.append(nid)
        self._mat = None

    def search(self, emb: np.ndarray) -> Tuple[int, float]:
        if not self._embs:
            return -1, -1.0
        if self._mat is None:
            self._mat = np.stack(self._embs)
        sims = self._mat @ emb.astype(np.float32)
        i = int(np.argmax(sims))
        return self._ids[i], float(sims[i])

    def __len__(self):
        return len(self._embs)


class ReusableMCTS:
    """Shares MCTS statistics across queries through embedding-matched
    states. ``embed_fn(plan) -> np.ndarray`` is Query2Vec.

    Warm starts are two-layer: a query whose root embedding collides with a
    well-visited stored node gets the reduced ``warm_iterations`` budget,
    and its first iteration *replays* the stored node's best known rule
    chain (``_RNode.best_seq``) — each rule re-configured for the concrete
    query by ``configure_action``, inapplicable steps skipped — before the
    remaining iterations search normally. The serving tier primes exactly
    this structure from live traffic (``repro.serving.feedback``): one full
    optimization per hot signature deposits its best chain in the
    ``NodeIndex``-matched root, so the next same-family query reaches a
    comparable plan in a fraction of the iterations."""

    def __init__(self, catalog_fn, embed_fn, cost_fn_factory,
                 iterations: int = 40, warm_iterations: int = 10,
                 c: float = 0.7, max_depth: int = 6, sim_threshold: float = 0.9995,
                 seed: int = 0, actions: Optional[List[str]] = None):
        self.embed_fn = embed_fn
        self.cost_fn_factory = cost_fn_factory
        self.iterations = iterations
        self.warm_iterations = warm_iterations
        self.c = c
        self.max_depth = max_depth
        self.sim_threshold = sim_threshold
        self.rng = random.Random(seed)
        self.actions = actions or ACTION_SPACE
        self.nodes: List[_RNode] = []
        self.index = NodeIndex()
        self.queries = 0
        self.collisions = 0

    # -- node store -------------------------------------------------------
    def _get_or_create(self, emb: np.ndarray) -> Tuple[_RNode, bool]:
        nid, sim = self.index.search(emb)
        if nid >= 0 and sim >= self.sim_threshold:
            return self.nodes[nid], True
        node = _RNode(nid=len(self.nodes), embed=emb)
        self.nodes.append(node)
        self.index.add(node.nid, emb)
        return node, False

    def storage_bytes(self) -> int:
        return sum(n.storage_bytes() for n in self.nodes)

    # -- search (Alg. 5) ----------------------------------------------------
    def optimize(self, plan: ir.Plan, catalog: ir.Catalog) -> Tuple[ir.Plan, Dict]:
        cost_fn = self.cost_fn_factory(catalog)
        emb0 = self.embed_fn(plan, catalog)
        root, hit = self._get_or_create(emb0)
        self.queries += 1
        if hit:
            self.collisions += 1
        warm = hit and root.n > 0
        iters = self.warm_iterations if warm else self.iterations
        root_cost = cost_fn(plan)
        best_plan, best_cost = plan, root_cost
        best_seq: Tuple[str, ...] = ()
        replayed = False

        for it in range(iters):
            # warm start, layer 2: the first warm iteration replays the
            # matched root's best known rule chain, re-configured for this
            # concrete query (skipping inapplicable steps). Embedding
            # collapse can poison child/dead bookkeeping across queries,
            # so the sketch — not the UCB statistics — is what reliably
            # transfers a good plan to a structural sibling.
            replay = (list(root.best_seq)
                      if (warm and it == 0 and root.best_seq) else None)
            node = root
            cur_plan, cur_cost = plan, root_cost
            depth = 0
            path = [node]
            applied: list = []
            while depth < self.max_depth:
                if node.untried is None:
                    node.untried = [a for a in self.actions if a not in node.dead]
                if replay is not None:
                    if not replay:
                        break
                    a = replay.pop(0)
                else:
                    # well-visited nodes (warm-started from a previous
                    # query's search) exploit their known-good children
                    # first; fresh nodes explore untried actions (standard
                    # MCTS expansion)
                    exploit = node.children and node.n >= 8
                    if node.untried and not exploit:
                        a = self.rng.choice(node.untried)
                        node.untried.remove(a)
                    else:
                        a = self._ucb(node)
                        if a is None:
                            if node.untried:
                                a = self.rng.choice(node.untried)
                                node.untried.remove(a)
                            else:
                                break
                res = configure_action(cur_plan, catalog, a, cost_fn)
                if res is None:
                    if replay is None:
                        # replayed steps don't mark shared state dead: the
                        # rule may be inapplicable only for *this* query
                        node.dead.add(a)
                        node.children.pop(a, None)
                    continue
                cur_plan, _ = res
                cur_cost = cost_fn(cur_plan)
                if replay is not None and node.untried and a in node.untried:
                    # an applied replay step counts as this node's expansion
                    # of that action — later iterations must not re-try it
                    node.untried.remove(a)
                emb = self.embed_fn(cur_plan, catalog)
                if a in node.children:
                    child = self.nodes[node.children[a]]
                else:
                    child, _ = self._get_or_create(emb)
                    node.children[a] = child.nid
                node = child
                path.append(node)
                depth += 1
                applied.append(a)
                if cur_cost < best_cost:
                    best_plan, best_cost = cur_plan, cur_cost
                    best_seq = tuple(applied)
            if replay is not None and applied:
                replayed = True  # at least one stored step actually applied
            reward = (root_cost - cur_cost) / max(root_cost, 1e-12)
            for nd in path:
                nd.n += 1
                nd.r += reward
        gain = root_cost / max(best_cost, 1e-12)
        if best_seq and gain > max(root.best_gain, 1.0 + 1e-3):
            root.best_seq, root.best_gain = best_seq, gain
        return best_plan, {"root_cost": root_cost, "best_cost": best_cost,
                           "speedup": gain, "collision": hit,
                           "iterations": iters, "replayed": replayed}

    def _ucb(self, node: _RNode) -> Optional[str]:
        best_a, best_v = None, -float("inf")
        for a, cid in node.children.items():
            ch = self.nodes[cid]
            v = ch.r / max(ch.n, 1) + self.c * math.sqrt(
                math.log(max(node.n, 1) + 1) / max(ch.n, 1))
            if v > best_v:
                best_a, best_v = a, v
        return best_a

    @property
    def collision_rate(self) -> float:
        return self.collisions / max(self.queries, 1)
