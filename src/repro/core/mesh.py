"""Device-mesh utilities for the sharded execution path (``backend="sharded"``).

The serving tier's micro-batch axis is embarrassingly parallel: B stacked
same-signature queries need no cross-query communication, so the batch axis
of a vmapped plan body can be split over a 1-D device mesh with ``shard_map``
and no operator changes. This module owns the mesh plumbing for that path:

* ``data_mesh``      — a 1-D mesh over the host's devices, batch axis only.
* ``batch_ways``     — total shard count over the mesh's batch axes.
* ``shard_spec``     — the batch PartitionSpec, via the same
                       divisibility-fitting policy the model stack uses
                       (``repro.models.sharding.batch_spec``): shard only
                       when the batch divides the device count, else
                       replicate.
* ``can_shard``      — eligibility predicate the plan cache and the serving
                       executor share: >1 device on the batch axes AND the
                       fitting policy actually sharded.
* ``mesh_signature`` — the mesh's contribution to compiled-plan cache keys.
* ``shard_batch``    — wrap a stacked-batch function in ``shard_map`` over
                       the mesh's batch axes (jax-version compatible).

It is also the *one* home of the intra-query partition arithmetic the
PartSpec layer uses (``repro.core.physical.PartSpec`` /
``PRepartition``): ``row_block`` / ``padded_capacity`` size the per-device
row blocks of a row-partitioned operator, ``hash_bucket`` is the join-key
bucketing function of hash-partitioned ``PJoin``, and
``shard_replicated`` wraps a whole partitioned plan body in ``shard_map``
with replicated inputs/outputs (the collectives live *inside* the plan as
explicit repartition ops). The production/host mesh builders formerly in
``repro.launch.mesh`` live here too — that module re-exports them — so
every mesh helper has exactly one definition.
"""
from __future__ import annotations

from typing import Callable, Optional, Sequence

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.models.sharding import batch_axes, batch_spec

DATA_AXIS = "data"


def data_mesh(n_devices: Optional[int] = None, *,
              devices: Optional[Sequence] = None,
              axis: str = DATA_AXIS) -> Mesh:
    """A 1-D mesh over (a prefix of) the host's devices.

    The single axis is the micro-batch/data axis; there is no model axis —
    the sharded execution path replicates weights and splits only the
    stacked batch dimension. ``axis`` must be a name the batch-axis policy
    recognizes (``models.sharding.batch_axes``), otherwise the mesh would
    silently never shard anything.
    """
    if axis not in ("pod", DATA_AXIS):
        raise ValueError(
            f"axis {axis!r} is not a recognized batch axis "
            f"('pod'/'{DATA_AXIS}'): can_shard would always be False")
    if devices is None:
        devices = jax.devices()
    devices = list(devices)
    if n_devices is not None:
        if not 1 <= n_devices <= len(devices):
            raise ValueError(
                f"n_devices={n_devices} out of range for "
                f"{len(devices)} visible device(s)")
        devices = devices[:n_devices]
    return Mesh(np.asarray(devices), (axis,))


def batch_ways(mesh: Mesh) -> int:
    """Total shard count over the mesh's batch axes (pod x data)."""
    ways = 1
    for a in batch_axes(mesh):
        ways *= mesh.shape[a]
    return ways


def shard_spec(mesh: Mesh, batch_size: int) -> P:
    """Batch-axis PartitionSpec under the divisibility-fitting policy."""
    return batch_spec(mesh, batch_size)


def can_shard(mesh: Optional[Mesh], batch_size: int) -> bool:
    """True iff the mesh would actually split ``batch_size``: more than one
    device on the batch axes and the fitting policy sharded (batch divides
    the device count). Everything else falls back to the single-device
    vmapped program."""
    if mesh is None or batch_ways(mesh) <= 1:
        return False
    return any(ax is not None for ax in shard_spec(mesh, batch_size))


def mesh_signature(mesh: Mesh) -> str:
    """The mesh's contribution to a compiled-plan cache key: axis layout and
    per-axis size (device *identity* doesn't change the traced program)."""
    return "x".join(f"{a}={mesh.shape[a]}" for a in mesh.axis_names)


def shard_batch(fn: Callable, mesh: Mesh) -> Callable:
    """``shard_map`` a stacked-batch function over the mesh's batch axes.

    ``fn`` takes / returns pytrees whose every leaf has the stacked batch as
    its leading axis; each device runs ``fn`` on its ``batch/ways`` slice.
    Callers must have checked ``can_shard`` — the spec here is
    unconditional. Weights and other closed-over arrays are replicated.
    """
    try:  # jax >= 0.6
        from jax import shard_map as _shard_map
    except ImportError:
        from jax.experimental.shard_map import shard_map as _shard_map
    spec = P(batch_axes(mesh))
    # disable replication checking: the plan body is arbitrary jnp code over
    # closed-over (replicated) weights; the checker rejects some primitives
    # it cannot type, and we never rely on rep types. The kwarg was renamed
    # check_rep -> check_vma across jax versions; try both before falling
    # back to the (checked) default.
    for kw in ({"check_rep": False}, {"check_vma": False}, {}):
        try:
            return _shard_map(fn, mesh=mesh, in_specs=spec, out_specs=spec,
                              **kw)
        except TypeError:
            continue
    raise TypeError("shard_map signature not recognized")


def shard_replicated(fn: Callable, mesh: Mesh) -> Callable:
    """``shard_map`` a *partitioned plan body* over the mesh: inputs and
    outputs are replicated (every device sees the full catalog tables and
    produces the full result), and all data movement happens through the
    explicit ``PRepartition`` collectives inside ``fn`` (slice /
    all_gather / psum against ``jax.lax.axis_index``). This is the
    single-oversized-query counterpart of ``shard_batch``: there is no
    stacked batch axis to split, the *operators* are partitioned instead.
    """
    try:  # jax >= 0.6
        from jax import shard_map as _shard_map
    except ImportError:
        from jax.experimental.shard_map import shard_map as _shard_map
    spec = P()  # replicated in/out; movement is explicit inside the body
    for kw in ({"check_rep": False}, {"check_vma": False}, {}):
        try:
            return _shard_map(fn, mesh=mesh, in_specs=spec, out_specs=spec,
                              **kw)
        except TypeError:
            continue
    raise TypeError("shard_map signature not recognized")


# ---------------------------------------------------------------------------
# intra-query partition arithmetic (the PartSpec layer's shared helpers)
# ---------------------------------------------------------------------------

def row_block(capacity: int, ways: int) -> int:
    """Per-device row-block size of a ``ways``-way row partition of a
    ``capacity``-row table: ``ceil(capacity / ways)`` — non-dividing
    capacities pad the tail with invalid rows (``padded_capacity``)."""
    if ways < 1:
        raise ValueError(f"ways must be >= 1, got {ways}")
    return -(-int(capacity) // ways)


def padded_capacity(capacity: int, ways: int) -> int:
    """Smallest multiple of ``row_block`` covering ``capacity``: the shape
    row-partitioned blocks re-concatenate to before the trailing padding
    rows (all invalid, all at the tail) are sliced off."""
    return row_block(capacity, ways) * ways


def hash_bucket(keys, ways: int):
    """Device bucket of each (integer) join key: ``key mod ways``.

    The single bucketing function of hash-partitioned joins — both join
    sides and the cost model must agree on it, so it lives here. ``jnp.mod``
    is non-negative for positive ``ways`` regardless of key sign."""
    return jnp.mod(jnp.asarray(keys, jnp.int32), jnp.int32(ways))


# ---------------------------------------------------------------------------
# production / host mesh builders (canonical home; repro.launch.mesh
# re-exports these — functions, never module-level constants: the dry-run
# must set XLA_FLAGS before any jax device state is touched)
# ---------------------------------------------------------------------------

def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 256 chips (16 data x 16 model). Multi-pod: 2 x 256."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(data: Optional[int] = None, model: int = 1):
    """Small mesh over the locally visible devices (tests / CPU runs)."""
    n = jax.device_count()
    data = data if data is not None else max(n // model, 1)
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
