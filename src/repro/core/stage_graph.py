"""Stage-DAG IR: the candidate space of lowering decisions.

``build`` turns a logical plan into a small DAG that mirrors the physical
operator tree but keeps lowering's *choices* open instead of fixing them in
tree order:

* **stage order** — each fused row-local pipeline holds its stages as
  vertices with precedence edges (column read/write conflicts, keep-project
  barriers, filter/compact ordering legality); any topological order is a
  legal realization. Filters keep their relative tree order (reordering
  them is cost-neutral under the capacity-driven model, and fixing them
  keeps every compaction bound sound).
* **compaction placement** — a Filter with a *sound* live-row bound (an
  exact numpy count of its scan-level predicate-chain conjunction, ML
  calls included; never a selectivity estimate — a wrong bound would drop
  rows) offers an optional ``Compact`` stage glued right after it,
  capacity rounded up (headroom against parameterized traffic, same
  policy as the ``compact`` co-optimization rule).
* **realization** — each BlockedMatmul/ForestRelational node that the
  optimizer did *not* explicitly annotate offers mode x backend candidates
  (pallas only on profiles that support it). Explicit ``Plan.phys``
  annotations and caller ``backend=`` overrides are sovereign: the rule
  engine / caller chose, lowering does not second-guess.

``core.costed_lowering`` enumerates the site options and scores realized
candidates through the shared ``cost.plan_cost`` oracle; ``realize`` with
``default_decisions`` reproduces tree-order lowering exactly.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import evaluator, ir
from repro.core import physical as ph
from repro.mlfuncs.registry import Registry

# plan-level realizations resolve per-node to the pure-XLA path (the sharded
# path splits the stacked batch axis *around* the plan body) — kept in sync
# with repro.core.lowering._PLAN_LEVEL_BACKENDS
PLAN_LEVEL_BACKENDS = {"sharded": "jnp"}

_ROW_LOCAL = (ir.Filter, ir.Project, ir.Compact)

# enumeration bound: per-pipeline topological orders
ORDER_CAP = 8
# exact-count budget: predicate chains are only counted on base tables up
# to this many rows (counting runs the predicate — including ML calls —
# once on the numpy base data; same spirit as the compact rule's 2M cap)
COUNT_ROWS_CAP = 200_000


def _round_up(n: int) -> int:
    """Next power of two >= n (min 8): compaction headroom, same policy as
    the ``compact`` rule in ``rules.o1``."""
    n = max(int(n), 8)
    p = 8
    while p < n:
        p *= 2
    return p


def compact_capacity(bound: float) -> int:
    """Compaction capacity for a sound live-row bound: the next power of
    two, or — when that doubles a large bound away — the next multiple of
    64 above 25% headroom. Headroom is what keeps the capacity a sound
    bound under drifting (parameterized) traffic, same intent as the
    ``compact`` rule's power-of-two policy."""
    b = int(np.ceil(bound))
    return max(min(_round_up(b), int(-(-int(b * 1.25) // 64)) * 64), 8)


# ---------------------------------------------------------------------------
# pipeline vertices + legality edges
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class StageVertex:
    stage: ph.Stage
    reads: frozenset
    writes: frozenset
    is_filter: bool = False
    is_compact: bool = False
    barrier: bool = False  # keep-projects drop columns: nothing crosses


def _vertex(node: ir.RelNode) -> StageVertex:
    if isinstance(node, ir.Filter):
        return StageVertex(ph.FilterStage(pred=node.pred),
                           reads=frozenset(node.pred.cols()),
                           writes=frozenset(), is_filter=True)
    if isinstance(node, ir.Project):
        reads = frozenset().union(*(e.cols() for _, e in node.outputs)) \
            if node.outputs else frozenset()
        return StageVertex(ph.ProjectStage(outputs=node.outputs,
                                           keep=node.keep),
                           reads=reads,
                           writes=frozenset(n for n, _ in node.outputs),
                           barrier=node.keep is not None)
    if isinstance(node, ir.Compact):
        return StageVertex(ph.CompactStage(capacity=node.capacity),
                           reads=frozenset(), writes=frozenset(),
                           is_compact=True)
    raise TypeError(type(node))


def _edges(vertices: Tuple[StageVertex, ...]) -> frozenset:
    """Precedence edges (i, j): vertex i must stay before vertex j."""
    out = set()
    n = len(vertices)
    for i in range(n):
        for j in range(i + 1, n):
            a, b = vertices[i], vertices[j]
            if (a.barrier or b.barrier
                    or (a.writes & b.reads) or (a.reads & b.writes)
                    or (a.writes & b.writes)
                    # filters keep tree order (cost-neutral; keeps every
                    # compaction bound's filter-conjunction sound)
                    or (a.is_filter and b.is_filter)
                    or (a.is_compact and b.is_compact)
                    # a compact may move *later* across a filter (its bound
                    # held before the filter), never earlier across one
                    or (a.is_filter and b.is_compact)):
                out.add((i, j))
    return frozenset(out)


def _topo_orders(n: int, edges: frozenset, cap: int = ORDER_CAP
                 ) -> Tuple[Tuple[int, ...], ...]:
    """Up to ``cap`` topological orders; index order first, so option 0 is
    always the tree order."""
    preds = {j: {i for (i, jj) in edges if jj == j} for j in range(n)}
    out: List[Tuple[int, ...]] = []

    def rec(prefix: List[int], remaining: List[int]):
        if len(out) >= cap:
            return
        if not remaining:
            out.append(tuple(prefix))
            return
        placed = set(prefix)
        for v in remaining:
            if preds[v] <= placed:
                rec(prefix + [v], [r for r in remaining if r != v])
                if len(out) >= cap:
                    return

    rec([], list(range(n)))
    return tuple(out)


# ---------------------------------------------------------------------------
# sound live-row bounds (compaction legality)
# ---------------------------------------------------------------------------

def _count_cache(catalog: ir.Catalog) -> Dict[tuple, Optional[int]]:
    """Per-catalog count cache, stored *on* the catalog so it dies with it
    (a module-level id(catalog)-keyed dict would both leak and risk serving
    a stale count when a freed catalog's id is reused)."""
    cache = getattr(catalog, "_stage_graph_counts", None)
    if cache is None:
        cache = {}
        catalog._stage_graph_counts = cache
    return cache


def _exact_chain_count(f: ir.Filter, registry: Registry,
                       catalog: ir.Catalog) -> Optional[int]:
    """Exact surviving-row count of a Filter whose subtree is a chain of
    Filters over a Scan — numpy evaluation of the predicate conjunction
    (ML calls included: the unified evaluator runs them under ``xp=np``)
    on the catalog's base data, cached on the catalog per (table,
    predicate chain). Exactness is what makes the count a *sound*
    compaction bound; a selectivity guess here would silently drop rows
    (``ops.compact``), which is why — like the ``compact`` rule — no
    estimate is ever accepted."""
    preds: List[ir.Expr] = []
    node: ir.RelNode = f
    while isinstance(node, ir.Filter):
        preds.append(node.pred)
        node = node.child
    if not isinstance(node, ir.Scan):
        return None
    npt = catalog.np_tables.get(node.table)
    if not npt or catalog.stats[node.table].rows > COUNT_ROWS_CAP:
        return None
    cache = _count_cache(catalog)
    key = (node.table, tuple(ir._expr_sig(p) for p in preds))
    if key in cache:
        return cache[key]
    try:
        mask = np.ones(catalog.stats[node.table].rows, dtype=bool)
        for p in preds:
            m = np.asarray(evaluator.eval_expr(p, npt, registry, xp=np))
            if m.ndim == 2 and m.shape[1] == 1:
                m = m[:, 0]
            mask &= np.broadcast_to(m.astype(bool), mask.shape)
        count: Optional[int] = int(mask.sum())
    except Exception:
        count = None
    cache[key] = count
    return count


def sound_rows_bound(node: ir.RelNode, registry: Registry,
                     catalog: ir.Catalog) -> Optional[float]:
    """An upper bound on the live rows leaving ``node`` that is *sound* for
    the catalog's data (exact counts and monotone propagation only) — the
    legality test for compaction insertion, where a wrong estimate would
    drop rows rather than merely slow the query."""
    if isinstance(node, ir.Scan):
        return float(catalog.stats[node.table].rows)
    if isinstance(node, ir.Filter):
        b = sound_rows_bound(node.child, registry, catalog)
        cnt = _exact_chain_count(node, registry, catalog)
        if cnt is not None:
            return float(cnt) if b is None else min(b, float(cnt))
        # NO selectivity estimates/hints here: this bound sizes a Compact
        # capacity, where an optimistic guess drops rows instead of merely
        # slowing the query. A filter only removes rows, so the child
        # bound stays sound.
        return b
    if isinstance(node, ir.Compact):
        b = sound_rows_bound(node.child, registry, catalog)
        return float(node.capacity) if b is None else min(b, float(node.capacity))
    if isinstance(node, (ir.Project, ir.BlockedMatmul, ir.ForestRelational)):
        return sound_rows_bound(node.child, registry, catalog)
    if isinstance(node, ir.Join):  # FK join: right side unique on key
        return sound_rows_bound(node.left, registry, catalog)
    if isinstance(node, ir.CrossJoin):
        lb = sound_rows_bound(node.left, registry, catalog)
        rb = sound_rows_bound(node.right, registry, catalog)
        return None if lb is None or rb is None else lb * rb
    if isinstance(node, ir.Aggregate):
        b = sound_rows_bound(node.child, registry, catalog)
        g = float(node.num_groups)
        return g if b is None else min(b, g)
    raise TypeError(type(node))


# ---------------------------------------------------------------------------
# graph nodes + decision sites
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Site:
    """One lowering decision: a named, bounded option set. ``default`` is
    the tree-order / off / as-annotated option."""
    sid: str
    kind: str      # 'order' | 'compact' | 'realize'
    options: tuple
    default: int = 0


class GNode:
    def children(self) -> Tuple["GNode", ...]:
        return ()


@dataclasses.dataclass(frozen=True)
class GScan(GNode):
    table: str


@dataclasses.dataclass(frozen=True)
class GPipeline(GNode):
    child: GNode
    vertices: Tuple[StageVertex, ...]
    order_sid: str
    # (site id, vertex index of the filter the optional compact glues to)
    compact_sids: Tuple[Tuple[str, int], ...]

    def children(self):
        return (self.child,)


@dataclasses.dataclass(frozen=True)
class GJoin(GNode):
    left: GNode
    right: GNode
    left_key: str
    right_key: str
    rprefix: str = ""

    def children(self):
        return (self.left, self.right)


@dataclasses.dataclass(frozen=True)
class GCrossJoin(GNode):
    left: GNode
    right: GNode
    aprefix: str = ""
    bprefix: str = ""

    def children(self):
        return (self.left, self.right)


@dataclasses.dataclass(frozen=True)
class GAggregate(GNode):
    child: GNode
    key: str
    aggs: tuple
    num_groups: int

    def children(self):
        return (self.child,)


@dataclasses.dataclass(frozen=True)
class GML(GNode):
    """BlockedMatmul / ForestRelational with an open realization choice."""
    child: GNode
    kind: str  # 'matmul' | 'forest'
    x_col: str
    out_col: str
    fn: str
    keep: Optional[Tuple[str, ...]]
    realize_sid: str

    def children(self):
        return (self.child,)


@dataclasses.dataclass
class StageGraph:
    root: GNode
    registry: Registry
    sites: Dict[str, Site]

    # -- decisions ---------------------------------------------------------
    def default_decisions(self) -> Dict[str, int]:
        return {sid: s.default for sid, s in self.sites.items()}

    def decision_signature(self, decisions: Dict[str, int]) -> str:
        """Compact, stable realization-vector token (plan-cache key part)."""
        parts = []
        for sid in sorted(self.sites):
            site = self.sites[sid]
            opt = site.options[decisions[sid]]
            if site.kind == "order":
                parts.append(f"{sid}=" + "".join(str(i) for i in opt))
            elif site.kind == "compact":
                parts.append(f"{sid}={'-' if opt is None else opt}")
            else:
                parts.append(f"{sid}={opt.signature()}")
        return ";".join(parts)

    def n_candidates(self) -> int:
        n = 1
        for s in self.sites.values():
            n *= len(s.options)
        return n

    # -- realization -------------------------------------------------------
    def realize(self, decisions: Dict[str, int]) -> ph.PhysicalPlan:
        return ph.PhysicalPlan(root=self._realize(self.root, decisions),
                               registry=self.registry)

    def _realize(self, node: GNode, d: Dict[str, int]) -> ph.PhysNode:
        if isinstance(node, GScan):
            return ph.PScan(table=node.table)
        if isinstance(node, GPipeline):
            order = self.sites[node.order_sid].options[d[node.order_sid]]
            glued = {}
            for sid, fidx in node.compact_sids:
                cap = self.sites[sid].options[d[sid]]
                if cap is not None:
                    glued[fidx] = cap
            stages: List[ph.Stage] = []
            for idx in order:
                stages.append(node.vertices[idx].stage)
                if idx in glued:
                    stages.append(ph.CompactStage(capacity=glued[idx]))
            return ph.PPipeline(child=self._realize(node.child, d),
                                stages=tuple(stages))
        if isinstance(node, GJoin):
            return ph.PJoin(left=self._realize(node.left, d),
                            right=self._realize(node.right, d),
                            left_key=node.left_key, right_key=node.right_key,
                            rprefix=node.rprefix)
        if isinstance(node, GCrossJoin):
            return ph.PCrossJoin(left=self._realize(node.left, d),
                                 right=self._realize(node.right, d),
                                 aprefix=node.aprefix, bprefix=node.bprefix)
        if isinstance(node, GAggregate):
            return ph.PAggregate(child=self._realize(node.child, d),
                                 key=node.key, aggs=node.aggs,
                                 num_groups=node.num_groups)
        if isinstance(node, GML):
            cfg = self.sites[node.realize_sid].options[d[node.realize_sid]]
            child = self._realize(node.child, d)
            if node.kind == "matmul":
                return ph.PBlockedMatmul(child=child, x_col=node.x_col,
                                         out_col=node.out_col, fn=node.fn,
                                         n_tiles=cfg.n_tiles, mode=cfg.mode,
                                         backend=cfg.backend, keep=node.keep)
            return ph.PForestRelational(child=child, x_col=node.x_col,
                                        out_col=node.out_col, fn=node.fn,
                                        mode=cfg.mode, backend=cfg.backend,
                                        keep=node.keep)
        raise TypeError(type(node))


# ---------------------------------------------------------------------------
# build
# ---------------------------------------------------------------------------

class _Builder:
    def __init__(self, plan: ir.Plan, catalog: ir.Catalog,
                 backend: Optional[str], profile):
        self.plan = plan
        self.catalog = catalog
        self.backend = backend
        self.profile = profile
        self.sites: Dict[str, Site] = {}
        self._n = 0

    def _sid(self, prefix: str) -> str:
        sid = f"{prefix}{self._n}"
        self._n += 1
        return sid

    def _realize_options(self, node) -> Tuple[ir.PhysConfig, ...]:
        cfg = self.plan.phys_for(node)  # resolves weight-derived n_tiles
        if self.backend is not None:
            be = PLAN_LEVEL_BACKENDS.get(self.backend, self.backend)
            return (ir.PhysConfig(mode=cfg.mode, backend=be,
                                  n_tiles=cfg.n_tiles),)
        if node.uid in (self.plan.phys or {}):
            # the optimizer chose explicitly (R3/R4-2); lowering does not
            # second-guess an annotation it cannot see the memory budget for
            return (cfg,)
        opts = [cfg]
        for mode in ("fused", "relational"):
            for be in (("jnp", "pallas") if self.profile.supports_pallas
                       else ("jnp",)):
                cand = ir.PhysConfig(mode=mode, backend=be,
                                     n_tiles=cfg.n_tiles)
                if cand != cfg:
                    opts.append(cand)
        return tuple(opts)

    def _pipeline(self, node: ir.RelNode) -> GPipeline:
        # maximal Filter/Project/Compact chain; stages run source-to-sink
        chain: List[ir.RelNode] = []
        cur = node
        while isinstance(cur, _ROW_LOCAL):
            chain.append(cur)
            cur = cur.children()[0]
        chain.reverse()  # source-to-sink
        vertices = tuple(_vertex(n) for n in chain)
        edges = _edges(vertices)

        # optional compaction after filters with a sound live-row bound
        compact_sids: List[Tuple[str, int]] = []
        for vi, (v, n) in enumerate(zip(vertices, chain)):
            if not v.is_filter:
                continue
            prev_compact = vi > 0 and vertices[vi - 1].is_compact
            next_compact = (vi + 1 < len(vertices)
                            and vertices[vi + 1].is_compact)
            if prev_compact or next_compact:  # don't stack compacts
                continue
            bound = sound_rows_bound(n, self.plan.registry, self.catalog)
            if bound is None:
                continue
            at_cap = ir.infer(n, self.plan.registry, self.catalog).capacity
            cap = compact_capacity(bound)
            # any real shrink is a candidate; the cost oracle arbitrates
            if cap < at_cap:
                sid = self._sid("c")
                self.sites[sid] = Site(sid, "compact", (None, cap), 0)
                compact_sids.append((sid, vi))

        # only enumerate orders when a compact (existing or insertable) can
        # actually move the capacity-driven cost
        has_compact = compact_sids or any(v.is_compact for v in vertices)
        orders = (_topo_orders(len(vertices), edges) if has_compact
                  else (tuple(range(len(vertices))),))
        osid = self._sid("p")
        self.sites[osid] = Site(osid, "order", orders, 0)
        return GPipeline(child=self.visit(cur), vertices=vertices,
                         order_sid=osid, compact_sids=tuple(compact_sids))

    def visit(self, node: ir.RelNode) -> GNode:
        if isinstance(node, _ROW_LOCAL):
            return self._pipeline(node)
        if isinstance(node, ir.Scan):
            return GScan(table=node.table)
        if isinstance(node, ir.Join):
            return GJoin(left=self.visit(node.left),
                         right=self.visit(node.right),
                         left_key=node.left_key, right_key=node.right_key,
                         rprefix=node.rprefix)
        if isinstance(node, ir.CrossJoin):
            return GCrossJoin(left=self.visit(node.left),
                              right=self.visit(node.right),
                              aprefix=node.aprefix, bprefix=node.bprefix)
        if isinstance(node, ir.Aggregate):
            return GAggregate(child=self.visit(node.child), key=node.key,
                              aggs=node.aggs, num_groups=node.num_groups)
        if isinstance(node, (ir.BlockedMatmul, ir.ForestRelational)):
            sid = self._sid("r")
            opts = self._realize_options(node)
            self.sites[sid] = Site(sid, "realize", opts, 0)
            return GML(child=self.visit(node.child),
                       kind=("matmul" if isinstance(node, ir.BlockedMatmul)
                             else "forest"),
                       x_col=node.x_col, out_col=node.out_col, fn=node.fn,
                       keep=node.keep, realize_sid=sid)
        raise TypeError(type(node))


def build(plan: ir.Plan, catalog: ir.Catalog, *,
          backend: Optional[str] = None, profile=None) -> StageGraph:
    """Stage-DAG of ``plan``'s lowering choices. ``backend`` force-overrides
    every realization's backend (plan-level realizations resolve per-node
    first); ``profile`` gates device-specific candidates (pallas)."""
    if profile is None:
        from repro.core.cost import default_profile
        profile = default_profile()
    b = _Builder(plan, catalog, backend, profile)
    root = b.visit(plan.root)
    return StageGraph(root=root, registry=plan.registry, sites=b.sites)
