"""Stage-DAG IR: the candidate space of lowering decisions.

``build`` turns a logical plan into a small DAG that mirrors the physical
operator tree but keeps lowering's *choices* open instead of fixing them in
tree order:

* **stage order** — each fused row-local pipeline holds its stages as
  vertices with precedence edges (column read/write conflicts, keep-project
  barriers, filter/compact ordering legality); any topological order is a
  legal realization. Filters keep their relative tree order (reordering
  them is cost-neutral under the capacity-driven model, and fixing them
  keeps every compaction bound sound).
* **compaction placement** — a Filter with a *sound* live-row bound (an
  exact numpy count of its scan-level predicate-chain conjunction, ML
  calls included; never a selectivity estimate — a wrong bound would drop
  rows) offers an optional ``Compact`` stage glued right after it,
  capacity rounded up (headroom against parameterized traffic, same
  policy as the ``compact`` co-optimization rule).
* **realization** — each BlockedMatmul/ForestRelational node that the
  optimizer did *not* explicitly annotate offers mode x backend candidates
  (pallas only on profiles that support it). Explicit ``Plan.phys``
  annotations and caller ``backend=`` overrides are sovereign: the rule
  engine / caller chose, lowering does not second-guess.
* **partitioning** (``ways > 1`` — the intra-query sharding path) — every
  pipeline without a Compact, every ML node, and both join kinds offer
  per-node ``PartSpec`` candidates: row-block partitioning over the
  mesh's data axis (joins: probe side partitioned, build replicated), and
  for ``PJoin`` additionally hash-bucket partitioning of both sides.
  ``realize`` inserts explicit ``PRepartition`` boundaries exactly where
  adjacent nodes' specs disagree (slice / allgather / bucket / combine)
  and records the chosen spec of every node in the physical plan's
  ``parts`` side table. A row-partitioned pipeline containing a Compact is
  split at its last compact stage — the prefix runs replicated (a
  per-block compact would reorder rows against the global compaction),
  the row-local suffix partitions.

``core.costed_lowering`` enumerates the site options and scores realized
candidates through the shared ``cost.plan_cost`` oracle; ``realize`` with
``default_decisions`` reproduces tree-order lowering exactly.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import evaluator, ir
from repro.core import physical as ph
from repro.mlfuncs.registry import Registry

# plan-level realizations resolve per-node to the pure-XLA path (the sharded
# path splits the stacked batch axis *around* the plan body) — kept in sync
# with repro.core.lowering._PLAN_LEVEL_BACKENDS
PLAN_LEVEL_BACKENDS = {"sharded": "jnp"}

_ROW_LOCAL = (ir.Filter, ir.Project, ir.Compact)

# enumeration bound: per-pipeline topological orders
ORDER_CAP = 8
# exact-count budget: predicate chains are only counted on base tables up
# to this many rows (counting runs the predicate — including ML calls —
# once on the numpy base data; same spirit as the compact rule's 2M cap)
COUNT_ROWS_CAP = 200_000


def _round_up(n: int) -> int:
    """Next power of two >= n (min 8): compaction headroom, same policy as
    the ``compact`` rule in ``rules.o1``."""
    n = max(int(n), 8)
    p = 8
    while p < n:
        p *= 2
    return p


def compact_capacity(bound: float) -> int:
    """Compaction capacity for a sound live-row bound: the next power of
    two, or — when that doubles a large bound away — the next multiple of
    64 above 25% headroom. Headroom is what keeps the capacity a sound
    bound under drifting (parameterized) traffic, same intent as the
    ``compact`` rule's power-of-two policy."""
    b = int(np.ceil(bound))
    return max(min(_round_up(b), int(-(-int(b * 1.25) // 64)) * 64), 8)


# ---------------------------------------------------------------------------
# pipeline vertices + legality edges
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class StageVertex:
    stage: ph.Stage
    reads: frozenset
    writes: frozenset
    is_filter: bool = False
    is_compact: bool = False
    barrier: bool = False  # keep-projects drop columns: nothing crosses


def _vertex(node: ir.RelNode) -> StageVertex:
    if isinstance(node, ir.Filter):
        return StageVertex(ph.FilterStage(pred=node.pred),
                           reads=frozenset(node.pred.cols()),
                           writes=frozenset(), is_filter=True)
    if isinstance(node, ir.Project):
        reads = frozenset().union(*(e.cols() for _, e in node.outputs)) \
            if node.outputs else frozenset()
        return StageVertex(ph.ProjectStage(outputs=node.outputs,
                                           keep=node.keep),
                           reads=reads,
                           writes=frozenset(n for n, _ in node.outputs),
                           barrier=node.keep is not None)
    if isinstance(node, ir.Compact):
        return StageVertex(ph.CompactStage(capacity=node.capacity),
                           reads=frozenset(), writes=frozenset(),
                           is_compact=True)
    raise TypeError(type(node))


def _edges(vertices: Tuple[StageVertex, ...]) -> frozenset:
    """Precedence edges (i, j): vertex i must stay before vertex j."""
    out = set()
    n = len(vertices)
    for i in range(n):
        for j in range(i + 1, n):
            a, b = vertices[i], vertices[j]
            if (a.barrier or b.barrier
                    or (a.writes & b.reads) or (a.reads & b.writes)
                    or (a.writes & b.writes)
                    # filters keep tree order (cost-neutral; keeps every
                    # compaction bound's filter-conjunction sound)
                    or (a.is_filter and b.is_filter)
                    or (a.is_compact and b.is_compact)
                    # a compact may move *later* across a filter (its bound
                    # held before the filter), never earlier across one
                    or (a.is_filter and b.is_compact)):
                out.add((i, j))
    return frozenset(out)


def _topo_orders(n: int, edges: frozenset, cap: int = ORDER_CAP
                 ) -> Tuple[Tuple[int, ...], ...]:
    """Up to ``cap`` topological orders; index order first, so option 0 is
    always the tree order."""
    preds = {j: {i for (i, jj) in edges if jj == j} for j in range(n)}
    out: List[Tuple[int, ...]] = []

    def rec(prefix: List[int], remaining: List[int]):
        if len(out) >= cap:
            return
        if not remaining:
            out.append(tuple(prefix))
            return
        placed = set(prefix)
        for v in remaining:
            if preds[v] <= placed:
                rec(prefix + [v], [r for r in remaining if r != v])
                if len(out) >= cap:
                    return

    rec([], list(range(n)))
    return tuple(out)


# ---------------------------------------------------------------------------
# sound live-row bounds (compaction legality)
# ---------------------------------------------------------------------------

def _count_cache(catalog: ir.Catalog) -> Dict[tuple, Optional[int]]:
    """Per-catalog count cache, stored *on* the catalog so it dies with it
    (a module-level id(catalog)-keyed dict would both leak and risk serving
    a stale count when a freed catalog's id is reused)."""
    cache = getattr(catalog, "_stage_graph_counts", None)
    if cache is None:
        cache = {}
        catalog._stage_graph_counts = cache
    return cache


def _exact_chain_count(f: ir.Filter, registry: Registry,
                       catalog: ir.Catalog) -> Optional[int]:
    """Exact surviving-row count of a Filter whose subtree is a chain of
    Filters over a Scan — numpy evaluation of the predicate conjunction
    (ML calls included: the unified evaluator runs them under ``xp=np``)
    on the catalog's base data, cached on the catalog per (table,
    predicate chain). Exactness is what makes the count a *sound*
    compaction bound; a selectivity guess here would silently drop rows
    (``ops.compact``), which is why — like the ``compact`` rule — no
    estimate is ever accepted."""
    preds: List[ir.Expr] = []
    node: ir.RelNode = f
    while isinstance(node, ir.Filter):
        preds.append(node.pred)
        node = node.child
    if not isinstance(node, ir.Scan):
        return None
    npt = catalog.np_tables.get(node.table)
    if not npt or catalog.stats[node.table].rows > COUNT_ROWS_CAP:
        return None
    cache = _count_cache(catalog)
    key = (node.table, tuple(ir._expr_sig(p) for p in preds))
    if key in cache:
        return cache[key]
    try:
        mask = np.ones(catalog.stats[node.table].rows, dtype=bool)
        for p in preds:
            m = np.asarray(evaluator.eval_expr(p, npt, registry, xp=np))
            if m.ndim == 2 and m.shape[1] == 1:
                m = m[:, 0]
            mask &= np.broadcast_to(m.astype(bool), mask.shape)
        count: Optional[int] = int(mask.sum())
    except Exception:
        count = None
    cache[key] = count
    return count


def sound_rows_bound(node: ir.RelNode, registry: Registry,
                     catalog: ir.Catalog) -> Optional[float]:
    """An upper bound on the live rows leaving ``node`` that is *sound* for
    the catalog's data (exact counts and monotone propagation only) — the
    legality test for compaction insertion, where a wrong estimate would
    drop rows rather than merely slow the query."""
    if isinstance(node, ir.Scan):
        return float(catalog.stats[node.table].rows)
    if isinstance(node, ir.Filter):
        b = sound_rows_bound(node.child, registry, catalog)
        cnt = _exact_chain_count(node, registry, catalog)
        if cnt is not None:
            return float(cnt) if b is None else min(b, float(cnt))
        # NO selectivity estimates/hints here: this bound sizes a Compact
        # capacity, where an optimistic guess drops rows instead of merely
        # slowing the query. A filter only removes rows, so the child
        # bound stays sound.
        return b
    if isinstance(node, ir.Compact):
        b = sound_rows_bound(node.child, registry, catalog)
        return float(node.capacity) if b is None else min(b, float(node.capacity))
    if isinstance(node, (ir.Project, ir.BlockedMatmul, ir.ForestRelational)):
        return sound_rows_bound(node.child, registry, catalog)
    if isinstance(node, ir.Join):  # FK join: right side unique on key
        return sound_rows_bound(node.left, registry, catalog)
    if isinstance(node, ir.CrossJoin):
        lb = sound_rows_bound(node.left, registry, catalog)
        rb = sound_rows_bound(node.right, registry, catalog)
        return None if lb is None or rb is None else lb * rb
    if isinstance(node, ir.Aggregate):
        b = sound_rows_bound(node.child, registry, catalog)
        g = float(node.num_groups)
        return g if b is None else min(b, g)
    raise TypeError(type(node))


# ---------------------------------------------------------------------------
# graph nodes + decision sites
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Site:
    """One lowering decision: a named, bounded option set. ``default`` is
    the tree-order / off / as-annotated option."""
    sid: str
    kind: str      # 'order' | 'compact' | 'realize' | 'part'
    options: tuple
    default: int = 0


class GNode:
    def children(self) -> Tuple["GNode", ...]:
        return ()


@dataclasses.dataclass(frozen=True)
class GScan(GNode):
    table: str


@dataclasses.dataclass(frozen=True)
class GPipeline(GNode):
    child: GNode
    vertices: Tuple[StageVertex, ...]
    order_sid: str
    # (site id, vertex index of the filter the optional compact glues to)
    compact_sids: Tuple[Tuple[str, int], ...]
    part_sid: Optional[str] = None

    def children(self):
        return (self.child,)


@dataclasses.dataclass(frozen=True)
class GJoin(GNode):
    left: GNode
    right: GNode
    left_key: str
    right_key: str
    rprefix: str = ""
    part_sid: Optional[str] = None

    def children(self):
        return (self.left, self.right)


@dataclasses.dataclass(frozen=True)
class GCrossJoin(GNode):
    left: GNode
    right: GNode
    aprefix: str = ""
    bprefix: str = ""
    part_sid: Optional[str] = None

    def children(self):
        return (self.left, self.right)


@dataclasses.dataclass(frozen=True)
class GAggregate(GNode):
    child: GNode
    key: str
    aggs: tuple
    num_groups: int

    def children(self):
        return (self.child,)


@dataclasses.dataclass(frozen=True)
class GML(GNode):
    """BlockedMatmul / ForestRelational with an open realization choice."""
    child: GNode
    kind: str  # 'matmul' | 'forest'
    x_col: str
    out_col: str
    fn: str
    keep: Optional[Tuple[str, ...]]
    realize_sid: str
    part_sid: Optional[str] = None

    def children(self):
        return (self.child,)


@dataclasses.dataclass
class StageGraph:
    root: GNode
    registry: Registry
    sites: Dict[str, Site]
    ways: int = 1  # >1 iff partition sites were built (intra-query sharding)
    # catalog the graph was built against (scan capacities for partition
    # boundary sizing); realize() needs it only when partition sites exist
    catalog: Optional[ir.Catalog] = None

    # -- decisions ---------------------------------------------------------
    def default_decisions(self) -> Dict[str, int]:
        return {sid: s.default for sid, s in self.sites.items()}

    def partitioned_decisions(self) -> Dict[str, int]:
        """The maximally row-partitioned decision vector: every partition
        site takes its row-block option, everything else stays at the
        default. The coordinate-descent seed for memory-budgeted lowering —
        partitioning usually only fits the budget when *every* heavy node
        partitions, which no single-site flip from the default reaches."""
        d = self.default_decisions()
        for sid, s in self.sites.items():
            if s.kind == "part":
                d[sid] = 1  # options[1] is the row-block spec
        return d

    def decision_signature(self, decisions: Dict[str, int]) -> str:
        """Compact, stable realization-vector token (plan-cache key part)."""
        parts = []
        for sid in sorted(self.sites):
            site = self.sites[sid]
            opt = site.options[decisions[sid]]
            if site.kind == "order":
                parts.append(f"{sid}=" + "".join(str(i) for i in opt))
            elif site.kind == "compact":
                parts.append(f"{sid}={'-' if opt is None else opt}")
            else:
                parts.append(f"{sid}={opt.signature()}")
        return ";".join(parts)

    def n_candidates(self) -> int:
        n = 1
        for s in self.sites.values():
            n *= len(s.options)
        return n

    # -- realization -------------------------------------------------------
    def realize(self, decisions: Dict[str, int]) -> ph.PhysicalPlan:
        self._spec_of: Dict[int, ph.PartSpec] = {}
        root, spec, gcap, lcap = self._realize(self.root, decisions)
        if spec.kind != "rep":
            # the query result is a single table: always end replicated
            root = self._convert(root, spec, ph.REPLICATED, gcap, lcap)
        parts: Dict[str, ph.PartSpec] = {}

        def walk(n: ph.PhysNode, path: str) -> None:
            s = self._spec_of.get(id(n), ph.REPLICATED)
            if s.kind != "rep":
                parts[path] = s
            for i, c in enumerate(n.children()):
                walk(c, f"{path}.{i}")

        walk(root, "r")
        ways = self.ways if parts else 1
        return ph.PhysicalPlan(root=root, registry=self.registry,
                               parts=parts, ways=ways)

    def _part_spec(self, node, d: Dict[str, int]) -> ph.PartSpec:
        sid = getattr(node, "part_sid", None)
        if sid is None:
            return ph.REPLICATED
        return self.sites[sid].options[d[sid]]

    def _boundary(self, node: ph.PhysNode, op: str, ways: int,
                  in_cap: int, out_cap: int, key: Optional[str],
                  spec: ph.PartSpec) -> ph.PhysNode:
        b = ph.PRepartition(child=node, op=op, ways=ways,
                            in_capacity=in_cap, out_capacity=out_cap, key=key)
        self._spec_of[id(b)] = spec
        return b

    def _convert(self, node: ph.PhysNode, frm: ph.PartSpec, to: ph.PartSpec,
                 gcap: int, local_cap: Optional[int] = None) -> ph.PhysNode:
        """Insert the PRepartition boundary chain converting ``frm`` into
        ``to`` (normalizing through replicated). ``gcap`` is the global
        capacity at this point; ``local_cap`` the per-device capacity of a
        row-partitioned ``node`` (defaults to the padded block size)."""
        if frm == to:
            return node
        cur, spec = node, frm
        if spec.kind == "hash" and spec != to:
            cur = self._boundary(cur, "combine", spec.ways, gcap, gcap, None,
                                 ph.REPLICATED)
            spec = ph.REPLICATED
        if spec.kind == "row" and spec != to:
            from repro.core import mesh as mesh_util
            local = (local_cap if local_cap is not None
                     else mesh_util.row_block(gcap, spec.ways))
            cur = self._boundary(cur, "allgather", spec.ways, local, gcap,
                                 None, ph.REPLICATED)
            spec = ph.REPLICATED
        if to.kind == "row":
            from repro.core import mesh as mesh_util
            blk = mesh_util.row_block(gcap, to.ways)
            cur = self._boundary(cur, "slice", to.ways, gcap, blk, None, to)
        elif to.kind == "hash":
            cur = self._boundary(cur, "bucket", to.ways, gcap, gcap, to.key,
                                 to)
        return cur

    def _realize(self, node: GNode, d: Dict[str, int]
                 ) -> Tuple[ph.PhysNode, ph.PartSpec, int, int]:
        """Returns (physical node, its PartSpec, global capacity, local
        per-device capacity). Global and local agree except under a row
        partition, where local is this device's block."""
        out = self._realize_inner(node, d)
        self._spec_of[id(out[0])] = out[1]
        return out

    def _realize_inner(self, node: GNode, d: Dict[str, int]
                       ) -> Tuple[ph.PhysNode, ph.PartSpec, int, int]:
        if isinstance(node, GScan):
            cap = (self.catalog.stats[node.table].capacity
                   if self.catalog is not None else 0)
            return ph.PScan(table=node.table), ph.REPLICATED, cap, cap
        if isinstance(node, GPipeline):
            spec = self._part_spec(node, d)
            child, cspec, gcap, lcap = self._realize(node.child, d)
            order = self.sites[node.order_sid].options[d[node.order_sid]]
            glued = {}
            for sid, fidx in node.compact_sids:
                cap = self.sites[sid].options[d[sid]]
                if cap is not None:
                    glued[fidx] = cap
            stages: List[ph.Stage] = []
            for idx in order:
                stages.append(node.vertices[idx].stage)
                if idx in glued:
                    stages.append(ph.CompactStage(capacity=glued[idx]))
            compacts = [i for i, st in enumerate(stages)
                        if isinstance(st, ph.CompactStage)]
            if spec.kind == "row" and compacts:
                # a per-block compact would reorder rows against the global
                # compaction, so the prefix through the LAST compact runs
                # replicated and only the (row-local) suffix partitions —
                # which is also where the expensive per-row ML projects live
                from repro.core import mesh as mesh_util
                child = self._convert(child, cspec, ph.REPLICATED, gcap,
                                      lcap)
                cut = compacts[-1] + 1
                pre = ph.PPipeline(child=child, stages=tuple(stages[:cut]))
                self._spec_of[id(pre)] = ph.REPLICATED
                for st in stages[:cut]:
                    if isinstance(st, ph.CompactStage):
                        gcap = st.capacity
                child = self._convert(pre, ph.REPLICATED, spec, gcap)
                return (ph.PPipeline(child=child, stages=tuple(stages[cut:])),
                        spec, gcap, mesh_util.row_block(gcap, spec.ways))
            child = self._convert(child, cspec, spec, gcap, lcap)
            if spec.kind == "row":
                from repro.core import mesh as mesh_util
                lcap = mesh_util.row_block(gcap, spec.ways)
            else:
                lcap = gcap
            for st in stages:  # compacts only reach here replicated
                if isinstance(st, ph.CompactStage):
                    gcap = lcap = st.capacity
            return (ph.PPipeline(child=child, stages=tuple(stages)),
                    spec, gcap, lcap)
        if isinstance(node, GJoin):
            spec = self._part_spec(node, d)
            left, ls, lg, ll = self._realize(node.left, d)
            right, rs, rg, rr = self._realize(node.right, d)
            if spec.kind == "row":      # probe partitioned, build replicated
                from repro.core import mesh as mesh_util
                left = self._convert(left, ls, spec, lg, ll)
                right = self._convert(right, rs, ph.REPLICATED, rg, rr)
                lloc = mesh_util.row_block(lg, spec.ways)
            elif spec.kind == "hash":   # both sides bucket-exchanged
                left = self._convert(
                    left, ls, dataclasses.replace(spec, key=node.left_key),
                    lg, ll)
                right = self._convert(
                    right, rs, dataclasses.replace(spec, key=node.right_key),
                    rg, rr)
                lloc = lg
            else:
                left = self._convert(left, ls, ph.REPLICATED, lg, ll)
                right = self._convert(right, rs, ph.REPLICATED, rg, rr)
                lloc = lg
            out_spec = (spec if spec.kind != "hash"
                        else dataclasses.replace(spec, key=node.left_key))
            return (ph.PJoin(left=left, right=right, left_key=node.left_key,
                             right_key=node.right_key, rprefix=node.rprefix),
                    out_spec, lg, lloc)
        if isinstance(node, GCrossJoin):
            spec = self._part_spec(node, d)
            left, ls, lg, ll = self._realize(node.left, d)
            right, rs, rg, rr = self._realize(node.right, d)
            right = self._convert(right, rs, ph.REPLICATED, rg, rr)
            if spec.kind == "row":      # left rows partitioned, right whole
                from repro.core import mesh as mesh_util
                left = self._convert(left, ls, spec, lg, ll)
                lloc = mesh_util.row_block(lg, spec.ways) * rg
            else:
                left = self._convert(left, ls, ph.REPLICATED, lg, ll)
                lloc = lg * rg
            return (ph.PCrossJoin(left=left, right=right,
                                  aprefix=node.aprefix, bprefix=node.bprefix),
                    spec, lg * rg, lloc)
        if isinstance(node, GAggregate):
            child, cspec, gcap, lcap = self._realize(node.child, d)
            child = self._convert(child, cspec, ph.REPLICATED, gcap, lcap)
            return (ph.PAggregate(child=child, key=node.key, aggs=node.aggs,
                                  num_groups=node.num_groups),
                    ph.REPLICATED, node.num_groups, node.num_groups)
        if isinstance(node, GML):
            spec = self._part_spec(node, d)
            cfg = self.sites[node.realize_sid].options[d[node.realize_sid]]
            child, cspec, gcap, lcap = self._realize(node.child, d)
            child = self._convert(child, cspec, spec, gcap, lcap)
            if spec.kind == "row":
                from repro.core import mesh as mesh_util
                lcap = mesh_util.row_block(gcap, spec.ways)
            else:
                lcap = gcap
            if node.kind == "matmul":
                pnode: ph.PhysNode = ph.PBlockedMatmul(
                    child=child, x_col=node.x_col, out_col=node.out_col,
                    fn=node.fn, n_tiles=cfg.n_tiles, mode=cfg.mode,
                    backend=cfg.backend, keep=node.keep)
            else:
                pnode = ph.PForestRelational(
                    child=child, x_col=node.x_col, out_col=node.out_col,
                    fn=node.fn, mode=cfg.mode, backend=cfg.backend,
                    keep=node.keep)
            return pnode, spec, gcap, lcap
        raise TypeError(type(node))


# ---------------------------------------------------------------------------
# build
# ---------------------------------------------------------------------------

class _Builder:
    def __init__(self, plan: ir.Plan, catalog: ir.Catalog,
                 backend: Optional[str], profile, ways: int = 1):
        self.plan = plan
        self.catalog = catalog
        self.backend = backend
        self.profile = profile
        self.ways = max(int(ways), 1)
        self.sites: Dict[str, Site] = {}
        self._n = 0

    def _sid(self, prefix: str) -> str:
        sid = f"{prefix}{self._n}"
        self._n += 1
        return sid

    def _part_site(self, *extra) -> Optional[str]:
        """A per-node PartSpec decision site: replicated (the default),
        row-block partitioned, plus any node-specific ``extra`` specs.
        Only built when lowering targets a multi-device mesh (ways > 1)."""
        if self.ways <= 1:
            return None
        opts = (ph.REPLICATED, ph.PartSpec(kind="row", ways=self.ways),
                *extra)
        sid = self._sid("pt")
        self.sites[sid] = Site(sid, "part", opts, 0)
        return sid

    def _realize_options(self, node) -> Tuple[ir.PhysConfig, ...]:
        cfg = self.plan.phys_for(node)  # resolves weight-derived n_tiles
        if self.backend is not None:
            be = PLAN_LEVEL_BACKENDS.get(self.backend, self.backend)
            return (ir.PhysConfig(mode=cfg.mode, backend=be,
                                  n_tiles=cfg.n_tiles),)
        if node.uid in (self.plan.phys or {}):
            # the optimizer chose explicitly (R3/R4-2); lowering does not
            # second-guess an annotation it cannot see the memory budget for
            return (cfg,)
        opts = [cfg]
        for mode in ("fused", "relational"):
            for be in (("jnp", "pallas") if self.profile.supports_pallas
                       else ("jnp",)):
                cand = ir.PhysConfig(mode=mode, backend=be,
                                     n_tiles=cfg.n_tiles)
                if cand != cfg:
                    opts.append(cand)
        return tuple(opts)

    def _pipeline(self, node: ir.RelNode) -> GPipeline:
        # maximal Filter/Project/Compact chain; stages run source-to-sink
        chain: List[ir.RelNode] = []
        cur = node
        while isinstance(cur, _ROW_LOCAL):
            chain.append(cur)
            cur = cur.children()[0]
        chain.reverse()  # source-to-sink
        vertices = tuple(_vertex(n) for n in chain)
        edges = _edges(vertices)

        # optional compaction after filters with a sound live-row bound
        compact_sids: List[Tuple[str, int]] = []
        for vi, (v, n) in enumerate(zip(vertices, chain)):
            if not v.is_filter:
                continue
            prev_compact = vi > 0 and vertices[vi - 1].is_compact
            next_compact = (vi + 1 < len(vertices)
                            and vertices[vi + 1].is_compact)
            if prev_compact or next_compact:  # don't stack compacts
                continue
            bound = sound_rows_bound(n, self.plan.registry, self.catalog)
            if bound is None:
                continue
            at_cap = ir.infer(n, self.plan.registry, self.catalog).capacity
            cap = compact_capacity(bound)
            # any real shrink is a candidate; the cost oracle arbitrates
            if cap < at_cap:
                sid = self._sid("c")
                self.sites[sid] = Site(sid, "compact", (None, cap), 0)
                compact_sids.append((sid, vi))

        # only enumerate orders when a compact (existing or insertable) can
        # actually move the capacity-driven cost
        has_compact = compact_sids or any(v.is_compact for v in vertices)
        orders = (_topo_orders(len(vertices), edges) if has_compact
                  else (tuple(range(len(vertices))),))
        osid = self._sid("p")
        self.sites[osid] = Site(osid, "order", orders, 0)
        return GPipeline(child=self.visit(cur), vertices=vertices,
                         order_sid=osid, compact_sids=tuple(compact_sids),
                         part_sid=self._part_site())

    def visit(self, node: ir.RelNode) -> GNode:
        if isinstance(node, _ROW_LOCAL):
            return self._pipeline(node)
        if isinstance(node, ir.Scan):
            return GScan(table=node.table)
        if isinstance(node, ir.Join):
            # row = probe (left) row-partitioned with the build side
            # replicated; hash = both sides bucket-exchanged on their keys
            return GJoin(left=self.visit(node.left),
                         right=self.visit(node.right),
                         left_key=node.left_key, right_key=node.right_key,
                         rprefix=node.rprefix,
                         part_sid=self._part_site(
                             ph.PartSpec(kind="hash", ways=self.ways,
                                         key=node.left_key)))
        if isinstance(node, ir.CrossJoin):
            return GCrossJoin(left=self.visit(node.left),
                              right=self.visit(node.right),
                              aprefix=node.aprefix, bprefix=node.bprefix,
                              part_sid=self._part_site())
        if isinstance(node, ir.Aggregate):
            return GAggregate(child=self.visit(node.child), key=node.key,
                              aggs=node.aggs, num_groups=node.num_groups)
        if isinstance(node, (ir.BlockedMatmul, ir.ForestRelational)):
            sid = self._sid("r")
            opts = self._realize_options(node)
            self.sites[sid] = Site(sid, "realize", opts, 0)
            return GML(child=self.visit(node.child),
                       kind=("matmul" if isinstance(node, ir.BlockedMatmul)
                             else "forest"),
                       x_col=node.x_col, out_col=node.out_col, fn=node.fn,
                       keep=node.keep, realize_sid=sid,
                       part_sid=self._part_site())
        raise TypeError(type(node))


def build(plan: ir.Plan, catalog: ir.Catalog, *,
          backend: Optional[str] = None, profile=None,
          ways: int = 1) -> StageGraph:
    """Stage-DAG of ``plan``'s lowering choices. ``backend`` force-overrides
    every realization's backend (plan-level realizations resolve per-node
    first); ``profile`` gates device-specific candidates (pallas).
    ``ways > 1`` additionally opens per-node ``PartSpec`` sites (intra-query
    sharding over a ``ways``-device data mesh)."""
    if profile is None:
        from repro.core.cost import default_profile
        profile = default_profile()
    b = _Builder(plan, catalog, backend, profile, ways=ways)
    root = b.visit(plan.root)
    return StageGraph(root=root, registry=plan.registry, sites=b.sites,
                      ways=max(int(ways), 1), catalog=catalog)
