"""Numpy evaluator for Call-free expressions — used by the optimizer to get
exact selectivities of simple predicates over base tables (the role of
catalog statistics/samples in the paper), so Compact capacities are sound."""
from __future__ import annotations

import numpy as np

from repro.core import ir


def eval_np(e: ir.Expr, t) -> np.ndarray:
    if isinstance(e, ir.Col):
        return t[e.name]
    if isinstance(e, ir.Const):
        return np.float32(e.value)
    if isinstance(e, ir.BinOp):
        a, b = eval_np(e.a, t), eval_np(e.b, t)
        return {"+": a + b, "-": a - b, "*": a * b,
                "/": a / np.where(b == 0, 1e-9, b)}[e.op]
    if isinstance(e, ir.Cmp):
        a, b = eval_np(e.a, t), eval_np(e.b, t)
        return {"<": a < b, ">": a > b, "<=": a <= b, ">=": a >= b,
                "==": a == b, "!=": a != b}[e.op]
    if isinstance(e, ir.BoolOp):
        vals = [eval_np(a, t).astype(bool) for a in e.args]
        if e.op == "and":
            out = vals[0]
            for v in vals[1:]:
                out = out & v
            return out
        if e.op == "or":
            out = vals[0]
            for v in vals[1:]:
                out = out | v
            return out
        return ~vals[0]
    if isinstance(e, ir.IsIn):
        a = eval_np(e.a, t).astype(np.int64)
        out = np.zeros_like(a, dtype=bool)
        for v in e.values:
            out |= a == v
        return out
    if isinstance(e, ir.IfExpr):
        return np.where(eval_np(e.cond, t).astype(bool), eval_np(e.t, t), eval_np(e.f, t))
    raise ValueError(f"np eval unsupported for {type(e)}")


def has_call(e: ir.Expr) -> bool:
    if isinstance(e, ir.Call):
        return True
    return any(has_call(c) for c in e.children())
