"""Co-optimization rules O1-O4 (paper Sec. II-A + Appendix A).

Every rule is result-preserving: ``tests/test_rules.py`` executes plan and
rewrite on random catalogs and compares canonical outputs.
"""
from repro.core.rules.base import Rule, RuleConfig, ALL_RULES, rule_by_name
from repro.core.rules import o1, o2, o3, o4  # noqa: F401  (registration side effects)

__all__ = ["Rule", "RuleConfig", "ALL_RULES", "rule_by_name"]
