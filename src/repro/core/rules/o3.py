"""O3 — tensor-relational transformation (TRA lineage).

R3-1: a large matMul inside a chain-shaped ML function becomes a
      BlockedMatmul relational pipeline over a weight-tile relation
      (paper Fig. 2). Default mode is the literal 'relational' realization;
      R4-2 may replace it with the pipelined 'fused' physical form.
R3-2: decision forest -> crossJoin(T, DF) + project + aggregate
      (ForestRelational node).
R3-3: distances-to-centroids -> centroid-relation form, expressed by
      expanding the opaque kmeans function into matMul+bias+argmin atoms
      (which makes it eligible for R3-1/R2-1 downstream — the composition
      story of Sec. II-A's closing example).
"""
from __future__ import annotations

import dataclasses
from typing import List

import numpy as np

from repro.core import ir
from repro.core.rules import base
from repro.core.rules.base import Rule, RuleConfig, register_rule, fresh_col
from repro.mlfuncs.functions import Atom, MLFunction, MLGraph, MLNode


MIN_TENSOR_BYTES = 16 * 1024  # only worth transforming sizeable weights


def _chain_split(g: MLGraph, idx: int):
    """Split a chain graph around node index idx -> (pre, node, post)."""
    nodes = g.nodes
    pre = nodes[:idx]
    post = nodes[idx + 1:]

    def as_chain(ns):
        if not ns:
            return None
        out_nodes, prev = [], ("in", 0)
        for i, n in enumerate(ns):
            out_nodes.append(MLNode(id=i, atom=n.atom, args=(prev,)))
            prev = ("node", i)
        return MLGraph(nodes=out_nodes, out=len(ns) - 1, n_inputs=1)

    return as_chain(pre), nodes[idx], as_chain(post)


@register_rule
class TensorRelationalMatmul(Rule):
    name = "R3-1"
    category = "O3"

    def configs(self, plan, catalog):
        out = []
        for p in base.all_paths(plan.root):
            n = base.node_at(plan.root, p)
            if not isinstance(n, ir.Project):
                continue
            for name, e in n.outputs:
                if not isinstance(e, ir.Call) or len(e.args) != 1:
                    continue
                fn = plan.registry.get(e.fn)
                if fn.graph is None or not base.is_chain(fn.graph):
                    continue
                for i, gn in enumerate(fn.graph.nodes):
                    if gn.atom.kind == "matmul" and gn.atom.param_bytes() >= MIN_TENSOR_BYTES:
                        out.append(RuleConfig.make(self.name, path=p, output=name,
                                                   fn=e.fn, idx=i))
        return out

    def apply(self, plan, catalog, cfg):
        registry = plan.registry.copy()
        fn = registry.get(cfg.get("fn"))
        pre, mm_node, post = _chain_split(fn.graph, cfg.get("idx"))
        w = np.asarray(mm_node.atom.params["w"])
        mm_name = registry.fresh_name(fn.name + "_mm")
        registry.replace(MLFunction(
            name=mm_name,
            graph=MLGraph([MLNode(0, Atom("matmul", {"w": w}), (("in", 0),))], 0, 1),
            n_inputs=1))
        proj = base.node_at(plan.root, cfg.get("path"))
        call = dict(proj.outputs)[cfg.get("output")]
        arg = call.args[0]
        child = proj.child
        child_schema = tuple(sorted(ir.infer(child, registry, catalog).schema))
        # stage 1: pre-chain (or raw column)
        if pre is None and isinstance(arg, ir.Col):
            x_col = arg.name
            stage = child
        else:
            x_col = fresh_col("x")
            if pre is None:
                stage_expr = arg
            else:
                pre_name = registry.fresh_name(fn.name + "_pre")
                registry.replace(MLFunction(name=pre_name, graph=pre, n_inputs=1))
                stage_expr = ir.Call(pre_name, (arg,))
            stage = ir.Project(child, outputs=((x_col, stage_expr),), keep=None)
        # stage 2: the tensor-relational matmul (physical realization is a
        # side-table annotation, not a logical-node field)
        y_col = fresh_col("y")
        bm = ir.BlockedMatmul(stage, x_col=x_col, out_col=y_col, fn=mm_name)
        phys = {**plan.phys,
                bm.uid: ir.PhysConfig(mode="relational", backend="jnp",
                                      n_tiles=ir.default_n_tiles(registry,
                                                                 mm_name))}
        # stage 3: post-chain + the rest of the original outputs
        if post is None:
            final_expr: ir.Expr = ir.Col(y_col)
        else:
            post_name = registry.fresh_name(fn.name + "_post")
            registry.replace(MLFunction(name=post_name, graph=post, n_inputs=1))
            final_expr = ir.Call(post_name, (ir.Col(y_col),))
        rest = tuple((n2, e2) for n2, e2 in proj.outputs if n2 != cfg.get("output"))
        keep = proj.keep if proj.keep is not None else child_schema
        top = ir.Project(bm, outputs=rest + ((cfg.get("output"), final_expr),),
                         keep=keep)
        root = base.replace_at(plan.root, cfg.get("path"), top)
        return ir.Plan(root, registry, phys)


@register_rule
class ForestToRelational(Rule):
    name = "R3-2"
    category = "O3"

    def configs(self, plan, catalog):
        out = []
        for p in base.all_paths(plan.root):
            n = base.node_at(plan.root, p)
            if not isinstance(n, ir.Project):
                continue
            for name, e in n.outputs:
                if not (isinstance(e, ir.Call) and len(e.args) == 1
                        and isinstance(e.args[0], ir.Col)):
                    continue
                fn = plan.registry.get(e.fn)
                if (fn.graph is not None and len(fn.graph.nodes) == 1
                        and fn.graph.nodes[0].atom.kind == "forest"):
                    out.append(RuleConfig.make(self.name, path=p, output=name,
                                               fn=e.fn))
        return out

    def apply(self, plan, catalog, cfg):
        proj = base.node_at(plan.root, cfg.get("path"))
        call = dict(proj.outputs)[cfg.get("output")]
        child_schema = tuple(sorted(ir.infer(proj.child, plan.registry, catalog).schema))
        fr = ir.ForestRelational(proj.child, x_col=call.args[0].name,
                                 out_col=cfg.get("output"), fn=cfg.get("fn"))
        phys = {**plan.phys,
                fr.uid: ir.PhysConfig(mode="relational", backend="jnp")}
        rest = tuple((n2, e2) for n2, e2 in proj.outputs if n2 != cfg.get("output"))
        keep = proj.keep if proj.keep is not None else child_schema
        if rest or proj.keep is not None:
            keep2 = tuple(keep) + ((cfg.get("output"),)
                                   if cfg.get("output") not in keep else ())
            top: ir.RelNode = ir.Project(fr, outputs=rest, keep=keep2)
        else:
            top = fr
        root = base.replace_at(plan.root, cfg.get("path"), top)
        return ir.Plan(root, plan.registry, phys)


@register_rule
class CentroidsToRelational(Rule):
    name = "R3-3"
    category = "O3"

    def configs(self, plan, catalog):
        out = []
        for p in base.all_paths(plan.root):
            n = base.node_at(plan.root, p)
            if not isinstance(n, ir.Project):
                continue
            for name, e in n.outputs:
                if not isinstance(e, ir.Call):
                    continue
                fn = plan.registry.get(e.fn)
                if fn.graph is None and hasattr(fn, "centroids"):
                    out.append(RuleConfig.make(self.name, path=p, output=name,
                                               fn=e.fn))
        return out

    def apply(self, plan, catalog, cfg):
        registry = plan.registry.copy()
        fn = registry.get(cfg.get("fn"))
        c = np.asarray(fn.centroids)  # type: ignore[attr-defined]
        w = (-2.0 * c.T).astype(np.float32)            # [d, k]
        b = np.sum(c * c, axis=1).astype(np.float32)   # [k]
        g = MLGraph(nodes=[
            MLNode(0, Atom("matmul", {"w": w}), (("in", 0),)),
            MLNode(1, Atom("bias", {"b": b}), (("node", 0),)),
            MLNode(2, Atom("argmin"), (("node", 1),)),
        ], out=2, n_inputs=1)
        new_name = registry.fresh_name(fn.name + "_rel")
        registry.replace(MLFunction(name=new_name, graph=g, n_inputs=1))
        proj = base.node_at(plan.root, cfg.get("path"))
        call = dict(proj.outputs)[cfg.get("output")]
        outs = tuple((n2, ir.Call(new_name, call.args) if n2 == cfg.get("output") else e2)
                     for n2, e2 in proj.outputs)
        root = base.replace_at(plan.root, cfg.get("path"),
                               dataclasses.replace(proj, outputs=outs))
        return ir.Plan(root, registry, plan.phys)
