"""O2 — factorized inference (paper R2-1/R2-3, Morpheus/LMFAO lineage).

R2-1 rewrites matMul(concat(x_S, x_R), W) into
matMul(x_S, W_S) + matMul(x_R, W_R) inside the bottom-level IR. The partial
matmuls then become independent single-input subgraphs, which R4-1-split +
R1-3 push below the join — eliminating the redundant compute the join's
row replication would cause (paper Fig. 1 / Fig. 12(d)).

R2-3 factorizes Euclidean distance over concatenated features:
dist([a,b],[c,d]) = sqrt(dist(a,c)^2 + dist(b,d)^2).
"""
from __future__ import annotations

import dataclasses
from typing import List

import numpy as np

from repro.core import ir
from repro.core.rules import base
from repro.core.rules.base import Rule, RuleConfig, register_rule
from repro.mlfuncs.functions import Atom, MLFunction, MLGraph, MLNode


def _project_calls(plan, catalog):
    """Yield (path, out_name, call_expr, child_schema) for Project outputs
    that are direct Calls."""
    for p in base.all_paths(plan.root):
        n = base.node_at(plan.root, p)
        if not isinstance(n, ir.Project):
            continue
        ci = ir.infer(n.child, plan.registry, catalog)
        for name, e in n.outputs:
            if isinstance(e, ir.Call):
                yield p, name, e, ci.schema


def _concat_matmul_nodes(g: MLGraph):
    """Yield (concat_node, matmul_node) pairs where matmul consumes a concat
    of graph inputs."""
    by_id = {n.id: n for n in g.nodes}
    for n in g.nodes:
        if n.atom.kind != "matmul" or len(n.args) != 1:
            continue
        r = n.args[0]
        if r[0] != "node":
            continue
        c = by_id[r[1]]
        if c.atom.kind != "concat":
            continue
        if all(a[0] == "in" for a in c.args):
            yield c, n


@register_rule
class FactorizeLinear(Rule):
    name = "R2-1"
    category = "O2"

    def configs(self, plan, catalog):
        out = []
        for p, name, call, schema in _project_calls(plan, catalog):
            fn = plan.registry.get(call.fn)
            if fn.graph is None or fn.n_inputs < 2:
                continue
            for c, m in _concat_matmul_nodes(fn.graph):
                out.append(RuleConfig.make(self.name, path=p, output=name,
                                           fn=call.fn, matmul=m.id))
        return out

    def apply(self, plan, catalog, cfg):
        registry = plan.registry.copy()
        fn = registry.get(cfg.get("fn"))
        g = fn.graph
        m = g.node(cfg.get("matmul"))
        c = g.node(m.args[0][1])
        w = np.asarray(m.atom.params["w"])
        # find the Call site to learn input dims
        proj = base.node_at(plan.root, cfg.get("path"))
        call = dict(proj.outputs)[cfg.get("output")]
        schema = ir.infer(proj.child, registry, catalog).schema
        in_dims = [max(ir.expr_dim(a, schema, registry), 1) for a in call.args]
        # split W rows by concat argument spans
        spans = []
        off = 0
        for r in c.args:
            d = in_dims[r[1]]
            spans.append((r[1], off, off + d))
            off += d
        assert off == w.shape[0], f"weight rows {w.shape[0]} != concat dim {off}"
        nid = g.fresh_id()
        new_nodes: List[MLNode] = []
        partial_refs = []
        for in_idx, lo, hi in spans:
            atom = Atom("matmul", {"w": w[lo:hi].copy()})
            new_nodes.append(MLNode(id=nid, atom=atom, args=(("in", in_idx),)))
            partial_refs.append(("node", nid))
            nid += 1
        # chain of adds
        acc = partial_refs[0]
        for ref in partial_refs[1:]:
            new_nodes.append(MLNode(id=nid, atom=Atom("add"), args=(acc, ref)))
            acc = ("node", nid)
            nid += 1
        g2 = base.replace_graph_node(g, m.id, new_nodes, acc[1])
        # drop the concat node if now unused
        g2 = _prune_unused(g2)
        new_name = registry.fresh_name(fn.name + "_fact")
        registry.replace(dataclasses.replace(fn, name=new_name, graph=g2))
        new_call = ir.Call(new_name, call.args)
        outs = tuple((n2, new_call if n2 == cfg.get("output") else e2)
                     for n2, e2 in proj.outputs)
        new_proj = dataclasses.replace(proj, outputs=outs)
        root = base.replace_at(plan.root, cfg.get("path"), new_proj)
        return ir.Plan(root, registry, plan.phys)


@register_rule
class FactorizeDistance(Rule):
    """R2-3: dist(concat(a,b), concat(c,d)) -> sqrt(d(a,c)^2 + d(b,d)^2)."""
    name = "R2-3"
    category = "O2"

    def configs(self, plan, catalog):
        out = []
        for p, name, call, schema in _project_calls(plan, catalog):
            fn = plan.registry.get(call.fn)
            if fn.graph is None:
                continue
            by_id = {n.id: n for n in fn.graph.nodes}
            for n in fn.graph.nodes:
                if n.atom.kind != "dist" or len(n.args) != 2:
                    continue
                if not all(r[0] == "node" and by_id[r[1]].atom.kind == "concat"
                           for r in n.args):
                    continue
                ca, cb = by_id[n.args[0][1]], by_id[n.args[1][1]]
                if len(ca.args) == len(cb.args) and all(
                        r[0] == "in" for r in ca.args + cb.args):
                    out.append(RuleConfig.make(self.name, path=p, output=name,
                                               fn=call.fn, dist=n.id))
        return out

    def apply(self, plan, catalog, cfg):
        registry = plan.registry.copy()
        fn = registry.get(cfg.get("fn"))
        g = fn.graph
        n = g.node(cfg.get("dist"))
        by_id = {x.id: x for x in g.nodes}
        ca, cb = by_id[n.args[0][1]], by_id[n.args[1][1]]
        new_nodes: List[MLNode] = []
        nid = g.fresh_id()
        sq_refs = []
        for ra, rb in zip(ca.args, cb.args):
            new_nodes.append(MLNode(id=nid, atom=Atom("dist"), args=(ra, rb)))
            dref = ("node", nid)
            nid += 1
            new_nodes.append(MLNode(id=nid, atom=Atom("mul"), args=(dref, dref)))
            sq_refs.append(("node", nid))
            nid += 1
        acc = sq_refs[0]
        for ref in sq_refs[1:]:
            new_nodes.append(MLNode(id=nid, atom=Atom("add"), args=(acc, ref)))
            acc = ("node", nid)
            nid += 1
        new_nodes.append(MLNode(id=nid, atom=Atom("sqrt"), args=(acc,)))
        g2 = base.replace_graph_node(g, n.id, new_nodes, nid)
        g2 = _prune_unused(g2)
        new_name = registry.fresh_name(fn.name + "_dfact")
        registry.replace(dataclasses.replace(fn, name=new_name, graph=g2))
        proj = base.node_at(plan.root, cfg.get("path"))
        call = dict(proj.outputs)[cfg.get("output")]
        outs = tuple((n2, ir.Call(new_name, call.args) if n2 == cfg.get("output") else e2)
                     for n2, e2 in proj.outputs)
        root = base.replace_at(plan.root, cfg.get("path"),
                               dataclasses.replace(proj, outputs=outs))
        return ir.Plan(root, registry, plan.phys)


def _prune_unused(g: MLGraph) -> MLGraph:
    needed = set()
    stack = [g.out]
    while stack:
        cur = stack.pop()
        if cur in needed:
            continue
        needed.add(cur)
        for r in g.node(cur).args:
            if r[0] == "node":
                stack.append(r[1])
    return MLGraph(nodes=[n for n in g.nodes if n.id in needed], out=g.out,
                   n_inputs=g.n_inputs)
