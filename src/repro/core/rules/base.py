"""Rule framework: plan-path addressing, expression/graph surgery helpers."""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

from repro.core import ir
from repro.mlfuncs.functions import Atom, MLFunction, MLGraph, MLNode

Path = Tuple[int, ...]


# ---------------------------------------------------------------------------
# path addressing over the immutable plan tree
# ---------------------------------------------------------------------------

def node_at(root: ir.RelNode, path: Path) -> ir.RelNode:
    n = root
    for i in path:
        n = n.children()[i]
    return n


def replace_at(root: ir.RelNode, path: Path, new: ir.RelNode) -> ir.RelNode:
    if not path:
        return new
    kids = list(root.children())
    kids[path[0]] = replace_at(kids[path[0]], path[1:], new)
    return root.with_children(kids)


def all_paths(root: ir.RelNode, path: Path = ()) -> List[Path]:
    out = [path]
    for i, c in enumerate(root.children()):
        out.extend(all_paths(c, path + (i,)))
    return out


# ---------------------------------------------------------------------------
# expression surgery
# ---------------------------------------------------------------------------

def subst_cols(e: ir.Expr, mapping: Dict[str, ir.Expr]) -> ir.Expr:
    if isinstance(e, ir.Col):
        return mapping.get(e.name, e)
    if isinstance(e, ir.Const):
        return e
    if isinstance(e, ir.BinOp):
        return ir.BinOp(e.op, subst_cols(e.a, mapping), subst_cols(e.b, mapping))
    if isinstance(e, ir.Cmp):
        return ir.Cmp(e.op, subst_cols(e.a, mapping), subst_cols(e.b, mapping))
    if isinstance(e, ir.BoolOp):
        return ir.BoolOp(e.op, tuple(subst_cols(a, mapping) for a in e.args))
    if isinstance(e, ir.IsIn):
        return ir.IsIn(subst_cols(e.a, mapping), e.values)
    if isinstance(e, ir.IfExpr):
        return ir.IfExpr(subst_cols(e.cond, mapping), subst_cols(e.t, mapping),
                         subst_cols(e.f, mapping))
    if isinstance(e, ir.Call):
        return ir.Call(e.fn, tuple(subst_cols(a, mapping) for a in e.args))
    raise TypeError(type(e))


def expr_calls(e: ir.Expr):
    if isinstance(e, ir.Call):
        yield e
    for c in e.children():
        yield from expr_calls(c)


# ---------------------------------------------------------------------------
# ML graph surgery (bottom-level IR rewrites)
# ---------------------------------------------------------------------------

def graph_users(g: MLGraph) -> Dict[int, List[int]]:
    users: Dict[int, List[int]] = {n.id: [] for n in g.nodes}
    for n in g.nodes:
        for r in n.args:
            if r[0] == "node":
                users[r[1]].append(n.id)
    return users


def ancestors(g: MLGraph, nid: int) -> List[int]:
    """Transitive producers of node nid (including nid), topo order."""
    keep = set()
    stack = [nid]
    while stack:
        cur = stack.pop()
        if cur in keep:
            continue
        keep.add(cur)
        for r in g.node(cur).args:
            if r[0] == "node":
                stack.append(r[1])
    return [n.id for n in g.nodes if n.id in keep]


def extract_subgraph(g: MLGraph, nid: int) -> Tuple[MLGraph, List[int]]:
    """Subgraph computing node nid. Returns (sub, input_order) where
    input_order lists original graph-input indices in sub-input order."""
    ids = ancestors(g, nid)
    in_order: List[int] = []
    for i in ids:
        for r in g.node(i).args:
            if r[0] == "in" and r[1] not in in_order:
                in_order.append(r[1])
    remap_in = {orig: k for k, orig in enumerate(in_order)}
    nodes = []
    for i in ids:
        n = g.node(i)
        args = tuple(("in", remap_in[r[1]]) if r[0] == "in" else r for r in n.args)
        nodes.append(MLNode(id=n.id, atom=n.atom, args=args))
    return MLGraph(nodes=nodes, out=nid, n_inputs=len(in_order)), in_order


def residual_graph(g: MLGraph, cut: int, new_input: int) -> MLGraph:
    """Graph with node ``cut`` replaced by graph input ``new_input``.
    Nodes used only to compute ``cut`` are dropped."""
    sub_ids = set(ancestors(g, cut))
    # nodes needed by the output, treating `cut` as an input
    needed = set()
    stack = [g.out]
    while stack:
        cur = stack.pop()
        if cur in needed or cur == cut:
            continue
        needed.add(cur)
        for r in g.node(cur).args:
            if r[0] == "node" and r[1] != cut:
                stack.append(r[1])
    nodes = []
    for n in g.nodes:
        if n.id not in needed:
            continue
        args = tuple(("in", new_input) if (r == ("node", cut)) else r for r in n.args)
        nodes.append(MLNode(id=n.id, atom=n.atom, args=args))
    assert g.out != cut, "cannot cut the output node"
    return MLGraph(nodes=nodes, out=g.out, n_inputs=new_input + 1)


def replace_graph_node(g: MLGraph, nid: int, new_nodes: List[MLNode],
                       new_out: int) -> MLGraph:
    """Replace node nid with a set of new nodes; refs to nid point at new_out."""
    nodes: List[MLNode] = []
    for n in g.nodes:
        if n.id == nid:
            nodes.extend(new_nodes)
            continue
        args = tuple(("node", new_out) if r == ("node", nid) else r for r in n.args)
        nodes.append(MLNode(id=n.id, atom=n.atom, args=args))
    out = new_out if g.out == nid else g.out
    return MLGraph(nodes=nodes, out=out, n_inputs=g.n_inputs)


def is_chain(g: MLGraph) -> bool:
    if g.n_inputs != 1:
        return False
    prev: Any = ("in", 0)
    for n in g.nodes:
        if n.args != (prev,):
            return False
        prev = ("node", n.id)
    return g.out == g.nodes[-1].id


# ---------------------------------------------------------------------------
# Rule base + registry
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RuleConfig:
    rule: str
    params: Tuple[Tuple[str, Any], ...]  # sorted kv pairs (hashable)

    def get(self, key, default=None):
        for k, v in self.params:
            if k == key:
                return v
        return default

    @staticmethod
    def make(rule: str, **kw) -> "RuleConfig":
        return RuleConfig(rule=rule, params=tuple(sorted(kw.items())))


class Rule:
    name: str = "?"
    category: str = "?"

    def configs(self, plan: ir.Plan, catalog: ir.Catalog) -> List[RuleConfig]:
        raise NotImplementedError

    def apply(self, plan: ir.Plan, catalog: ir.Catalog, cfg: RuleConfig) -> ir.Plan:
        raise NotImplementedError


ALL_RULES: Dict[str, Rule] = {}


def register_rule(cls):
    inst = cls()
    ALL_RULES[inst.name] = inst
    return cls


def rule_by_name(name: str) -> Rule:
    return ALL_RULES[name]


_fresh_counter = [0]


def fresh_col(base: str) -> str:
    _fresh_counter[0] += 1
    return f"_{base}{_fresh_counter[0]}"
