"""O1 — relational algebra optimization (ML as opaque UDFs).

R1-1 filter reorder, R1-2 filter pushdown, R1-3 project pushdown,
R1-4 merge/split, plus the TPU-physical ``compact`` action that makes
pushdowns pay (static-shape shrink; DESIGN.md Sec. 2).
"""
from __future__ import annotations

import dataclasses
from typing import List

import numpy as np

from repro.core import evaluator, ir
from repro.core.rules import base
from repro.core.rules.base import Rule, RuleConfig, register_rule


def _side_schemas(node, registry, catalog):
    li = ir.infer(node.left, registry, catalog)
    ri = ir.infer(node.right, registry, catalog)
    return li.schema, ri.schema


def _prefixes(node):
    if isinstance(node, ir.Join):
        return "", node.rprefix
    return node.aprefix, node.bprefix


def _strip_prefix(e: ir.Expr, prefix: str) -> ir.Expr:
    if not prefix:
        return e
    mapping = {}
    for c in e.cols():
        if c.startswith(prefix):
            mapping[c] = ir.Col(c[len(prefix):])
    return base.subst_cols(e, mapping)


@register_rule
class FilterReorder(Rule):
    """R1-1: swap two adjacent filters (cheap/selective first)."""
    name = "R1-1"
    category = "O1"

    def configs(self, plan, catalog):
        out = []
        for p in base.all_paths(plan.root):
            n = base.node_at(plan.root, p)
            if isinstance(n, ir.Filter) and isinstance(n.child, ir.Filter):
                out.append(RuleConfig.make(self.name, path=p))
        return out

    def apply(self, plan, catalog, cfg):
        p = cfg.get("path")
        outer = base.node_at(plan.root, p)
        inner = outer.child
        new = dataclasses.replace(
            inner, child=dataclasses.replace(outer, child=inner.child))
        return plan.replace_root(base.replace_at(plan.root, p, new))


@register_rule
class FilterPushdown(Rule):
    """R1-2: push a filter below a join/crossJoin side it only references."""
    name = "R1-2"
    category = "O1"

    def configs(self, plan, catalog):
        out = []
        for p in base.all_paths(plan.root):
            n = base.node_at(plan.root, p)
            if not isinstance(n, ir.Filter):
                continue
            if isinstance(n.child, (ir.Join, ir.CrossJoin)):
                ls, rs = _side_schemas(n.child, plan.registry, catalog)
                ap, bp = _prefixes(n.child)
                cols = n.pred.cols()
                if all(c.startswith(ap) and c[len(ap):] in ls for c in cols):
                    out.append(RuleConfig.make(self.name, path=p, side=0))
                if all(c.startswith(bp) and c[len(bp):] in rs for c in cols):
                    out.append(RuleConfig.make(self.name, path=p, side=1))
            elif isinstance(n.child, ir.Project):
                # commute below a project whose outputs the pred ignores —
                # the filter then runs before the (usually expensive) project
                made = {nm for nm, _ in n.child.outputs}
                if not (n.pred.cols() & made):
                    out.append(RuleConfig.make(self.name, path=p, side=-1))
        return out

    def apply(self, plan, catalog, cfg):
        p, side = cfg.get("path"), cfg.get("side")
        f = base.node_at(plan.root, p)
        if side == -1:  # Filter(Project(c)) -> Project(Filter(c))
            proj = f.child
            new = proj.with_children(
                (ir.Filter(proj.child, f.pred, selectivity=f.selectivity),))
            return plan.replace_root(base.replace_at(plan.root, p, new))
        join = f.child
        ap, bp = _prefixes(join)
        prefix = ap if side == 0 else bp
        pred = _strip_prefix(f.pred, prefix)
        kids = list(join.children())
        kids[side] = ir.Filter(kids[side], pred, selectivity=f.selectivity)
        return plan.replace_root(base.replace_at(plan.root, p, join.with_children(kids)))


@register_rule
class ProjectPushdown(Rule):
    """R1-3: push one project output below the join side it references."""
    name = "R1-3"
    category = "O1"

    def configs(self, plan, catalog):
        out = []
        for p in base.all_paths(plan.root):
            n = base.node_at(plan.root, p)
            if not isinstance(n, ir.Project):
                continue
            if isinstance(n.child, (ir.Filter, ir.Compact)):
                # commute one output through the filter/compact so it can
                # keep sinking toward the join (Fig. 4-3's multi-step push)
                mid_schema = ir.infer(n.child, plan.registry, catalog).schema
                for name, e in n.outputs:
                    if e.cols() and name not in mid_schema:
                        out.append(RuleConfig.make(self.name, path=p,
                                                   output=name, side=-1))
                continue
            if not isinstance(n.child, (ir.Join, ir.CrossJoin)):
                continue
            ls, rs = _side_schemas(n.child, plan.registry, catalog)
            ap, bp = _prefixes(n.child)
            join_keys = set()
            if isinstance(n.child, ir.Join):
                join_keys = {n.child.left_key}
            for name, e in n.outputs:
                cols = e.cols()
                if name in join_keys or not cols:
                    continue
                # (prefixed sides would need a rename through the join; our
                # workloads use unique column names + empty prefixes)
                if ap == "" and name not in rs and all(c in ls for c in cols):
                    out.append(RuleConfig.make(self.name, path=p, output=name, side=0))
                if bp == "" and name not in ls and all(c in rs for c in cols):
                    out.append(RuleConfig.make(self.name, path=p, output=name, side=1))
        return out

    def apply(self, plan, catalog, cfg):
        p, name, side = cfg.get("path"), cfg.get("output"), cfg.get("side")
        proj = base.node_at(plan.root, p)
        if side == -1:  # commute through Filter/Compact
            mid = proj.child
            e = dict(proj.outputs)[name]
            below = ir.Project(mid.child, outputs=((name, e),), keep=None)
            new_mid = mid.with_children((below,))
            rest = tuple((n2, e2) for n2, e2 in proj.outputs if n2 != name)
            keep = proj.keep
            if keep is not None:
                keep = tuple(keep) + ((name,) if name not in keep else ())
            if rest or keep is not None:
                top: ir.RelNode = ir.Project(new_mid, outputs=rest, keep=keep)
            else:
                top = new_mid
            return plan.replace_root(base.replace_at(plan.root, p, top))
        join = proj.child
        e = dict(proj.outputs)[name]
        pushed = ir.Project(join.children()[side], outputs=((name, e),), keep=None)
        kids = list(join.children())
        kids[side] = pushed
        new_join = join.with_children(kids)
        rest = tuple((n2, e2) for n2, e2 in proj.outputs if n2 != name)
        keep = proj.keep
        if keep is not None:
            keep = tuple(keep) + ((name,) if name not in keep else ())
        if rest or keep is not None:
            top: ir.RelNode = ir.Project(new_join, outputs=rest, keep=keep)
        else:
            top = new_join
        return plan.replace_root(base.replace_at(plan.root, p, top))


@register_rule
class FilterMerge(Rule):
    """R1-4a: merge two adjacent filters into one AND-ed filter."""
    name = "R1-4-merge"
    category = "O1"

    def configs(self, plan, catalog):
        out = []
        for p in base.all_paths(plan.root):
            n = base.node_at(plan.root, p)
            if isinstance(n, ir.Filter) and isinstance(n.child, ir.Filter):
                out.append(RuleConfig.make(self.name, path=p, kind="filter"))
            if (isinstance(n, ir.Project) and isinstance(n.child, ir.Project)
                    and n.keep is None and n.child.keep is None):
                inner_names = {nm for nm, _ in n.child.outputs}
                # only merge if outer exprs reference inner outputs at most once
                out.append(RuleConfig.make(self.name, path=p, kind="project"))
        return out

    def apply(self, plan, catalog, cfg):
        p = cfg.get("path")
        n = base.node_at(plan.root, p)
        if cfg.get("kind") == "filter":
            sel = None
            if n.selectivity is not None and n.child.selectivity is not None:
                sel = n.selectivity * n.child.selectivity
            new = ir.Filter(n.child.child,
                            ir.BoolOp("and", (n.child.pred, n.pred)),
                            selectivity=sel)
        else:
            inner = n.child
            mapping = {nm: e for nm, e in inner.outputs}
            outs = tuple((nm, base.subst_cols(e, mapping)) for nm, e in n.outputs)
            # inner outputs not overwritten by outer survive
            carried = tuple((nm, e) for nm, e in inner.outputs
                            if nm not in dict(outs))
            new = ir.Project(inner.child, outputs=carried + outs, keep=None)
        return plan.replace_root(base.replace_at(plan.root, p, new))


@register_rule
class FilterSplit(Rule):
    """R1-4b: split an AND filter / multi-output project (inverse of merge)."""
    name = "R1-4-split"
    category = "O1"

    def configs(self, plan, catalog):
        out = []
        for p in base.all_paths(plan.root):
            n = base.node_at(plan.root, p)
            if (isinstance(n, ir.Filter) and isinstance(n.pred, ir.BoolOp)
                    and n.pred.op == "and" and len(n.pred.args) >= 2):
                out.append(RuleConfig.make(self.name, path=p, kind="filter"))
            if isinstance(n, ir.Project) and len(n.outputs) >= 2 and n.keep is None:
                names = [nm for nm, _ in n.outputs]
                used = set()
                for _, e in n.outputs:
                    used |= e.cols()
                for nm in names:
                    if nm not in used:  # output independent of siblings
                        out.append(RuleConfig.make(self.name, path=p, kind="project",
                                                   output=nm))
        return out

    def apply(self, plan, catalog, cfg):
        p = cfg.get("path")
        n = base.node_at(plan.root, p)
        if cfg.get("kind") == "filter":
            first, rest = n.pred.args[0], n.pred.args[1:]
            inner = ir.Filter(n.child, first)
            outer_pred = rest[0] if len(rest) == 1 else ir.BoolOp("and", rest)
            new = ir.Filter(inner, outer_pred)
        else:
            nm = cfg.get("output")
            e = dict(n.outputs)[nm]
            rest = tuple((a, b) for a, b in n.outputs if a != nm)
            new = ir.Project(ir.Project(n.child, outputs=rest, keep=None),
                             outputs=((nm, e),), keep=None)
        return plan.replace_root(base.replace_at(plan.root, p, new))


@register_rule
class CompactAfterFilter(Rule):
    """Physical enabler (TPU adaptation of R1-2/R1-3 payoff): shrink the
    static capacity after a selective filter.

    XLA's static shapes make the capacity a *correctness* bound, so compaction
    uses exact live-row counts: cheap predicate evaluation on base-table
    statistics where possible, otherwise an (aggressively cached) count of
    the filter subtree — the role the paper's samples/statistics play, made
    exact because a wrong estimate here would drop rows rather than merely
    slow the query. See DESIGN.md Sec. 9 (changed assumptions)."""
    name = "compact"
    category = "O1"

    _count_cache: dict = {}

    def configs(self, plan, catalog):
        out = []
        for p in base.all_paths(plan.root):
            n = base.node_at(plan.root, p)
            if not isinstance(n, ir.Filter) or isinstance(n.child, ir.Compact):
                continue
            # don't stack compacts
            parent = base.node_at(plan.root, p[:-1]) if p else None
            if isinstance(parent, ir.Compact):
                continue
            bound = self._row_bound(n, plan, catalog)
            if bound is None:
                continue
            ci = ir.infer(n.child, plan.registry, catalog)
            cap = _round_up(bound)
            if cap < ci.capacity * 0.75:
                out.append(RuleConfig.make(self.name, path=p, capacity=cap))
        return out

    def _row_bound(self, f: ir.Filter, plan, catalog):
        if isinstance(f.child, ir.Scan) and not evaluator.has_call(f.pred):
            npt = catalog.np_tables[f.child.table]
            if npt:
                mask = evaluator.eval_expr(f.pred, npt, plan.registry, xp=np)
                return int(np.sum(mask))
        key = (id(catalog), ir.plan_signature(f))
        if key in self._count_cache:
            return self._count_cache[key]
        ci = ir.infer(f.child, plan.registry, catalog)
        if ci.capacity > 2_000_000:  # too big to count eagerly
            return None
        from repro.core import executor
        try:
            t = executor.execute(ir.Plan(f, plan.registry, plan.phys), catalog)
            bound = int(t.num_valid())
        except Exception:
            bound = None
        self._count_cache[key] = bound
        return bound

    def apply(self, plan, catalog, cfg):
        p = cfg.get("path")
        n = base.node_at(plan.root, p)
        new = ir.Compact(n, capacity=cfg.get("capacity"))
        return plan.replace_root(base.replace_at(plan.root, p, new))


def _round_up(n: int) -> int:
    n = max(int(n), 8)
    p = 8
    while p < n:
        p *= 2
    return p
