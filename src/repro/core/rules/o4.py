"""O4 — data-model cross optimization.

R4-1-split : cut a single-input-subset subgraph out of a high-level ML
             function and materialize it as its own Project column (paper
             Fig. 4-1/4-2 — splitting twoTowerModel into towers + cosSim).
R4-1-fuse  : fuse matMul->bias->act chains into a fused_dense operator.
R4-1-unfuse: the inverse split of fused_dense.
R4-2       : physical backend replacement (jnp <-> pallas kernels; the
             paper's CPU/GPU/sparse library choice).
R4-4       : constant folding inside expressions.
"""
from __future__ import annotations

import dataclasses
from typing import List

from repro.core import ir
from repro.core.rules import base
from repro.core.rules.base import Rule, RuleConfig, register_rule, fresh_col
from repro.mlfuncs.functions import Atom, MLFunction, MLGraph, MLNode

_MERGE_KINDS = ("concat", "cossim", "dot", "dist", "add", "mul")


@register_rule
class SplitDisjoint(Rule):
    name = "R4-1-split"
    category = "O4"

    def configs(self, plan, catalog):
        out = []
        for p in base.all_paths(plan.root):
            n = base.node_at(plan.root, p)
            if not isinstance(n, ir.Project):
                continue
            for name, e in n.outputs:
                if not isinstance(e, ir.Call):
                    continue
                fn = plan.registry.get(e.fn)
                if fn.graph is None or fn.n_inputs < 2:
                    continue
                deps = fn.graph.input_deps()
                all_in = frozenset(range(fn.n_inputs))
                for gn in fn.graph.nodes:
                    if gn.id == fn.graph.out:
                        continue
                    # cut at args of merge nodes whose subgraph uses a proper
                    # subset of inputs and does real work
                    if deps[gn.id] and deps[gn.id] != all_in and len(
                            base.ancestors(fn.graph, gn.id)) >= 2:
                        users = base.graph_users(fn.graph)[gn.id]
                        by_id = {x.id: x for x in fn.graph.nodes}
                        if any(by_id[u].atom.kind in _MERGE_KINDS for u in users):
                            out.append(RuleConfig.make(self.name, path=p,
                                                       output=name, fn=e.fn,
                                                       node=gn.id))
        return out

    def apply(self, plan, catalog, cfg):
        registry = plan.registry.copy()
        fn = registry.get(cfg.get("fn"))
        g = fn.graph
        cut = cfg.get("node")
        sub, in_order = base.extract_subgraph(g, cut)
        res = base.residual_graph(g, cut, new_input=g.n_inputs)
        # prune inputs the residual no longer touches (their argument
        # expressions — possibly expensive nested calls — must not be
        # evaluated at this level anymore)
        used = sorted({r[1] for n in res.nodes for r in n.args if r[0] == "in"})
        remap = {old: new for new, old in enumerate(used)}
        res_nodes = [
            type(n)(id=n.id, atom=n.atom,
                    args=tuple(("in", remap[r[1]]) if r[0] == "in" else r
                               for r in n.args))
            for n in res.nodes]
        res = type(res)(nodes=res_nodes, out=res.out, n_inputs=len(used))
        sub_name = registry.fresh_name(fn.name + "_sub")
        res_name = registry.fresh_name(fn.name + "_res")
        registry.replace(MLFunction(name=sub_name, graph=sub, n_inputs=sub.n_inputs))
        registry.replace(MLFunction(name=res_name, graph=res, n_inputs=res.n_inputs))
        proj = base.node_at(plan.root, cfg.get("path"))
        call = dict(proj.outputs)[cfg.get("output")]
        tmp = fresh_col("split")
        sub_call = ir.Call(sub_name, tuple(call.args[i] for i in in_order))
        below = ir.Project(proj.child, outputs=((tmp, sub_call),), keep=None)
        ext_args = tuple(call.args) + (ir.Col(tmp),)
        res_call = ir.Call(res_name, tuple(ext_args[i] for i in used))
        outs = tuple((n2, res_call if n2 == cfg.get("output") else e2)
                     for n2, e2 in proj.outputs)
        keep = proj.keep
        if keep is None:
            # drop the tmp column so the output schema is unchanged
            child_schema = ir.infer(proj.child, plan.registry, catalog).schema
            keep = tuple(sorted(child_schema))
        new_proj = ir.Project(below, outputs=outs, keep=keep)
        root = base.replace_at(plan.root, cfg.get("path"), new_proj)
        return ir.Plan(root, registry, plan.phys)


@register_rule
class FuseDense(Rule):
    name = "R4-1-fuse"
    category = "O4"

    def configs(self, plan, catalog):
        out = []
        seen = set()
        for p in base.all_paths(plan.root):
            n = base.node_at(plan.root, p)
            if not isinstance(n, ir.Project):
                continue
            for name, e in n.outputs:
                for call in base.expr_calls(e):
                    fn = plan.registry.get(call.fn)
                    if fn.graph is None:
                        continue
                    for trip in _fusable_triples(fn.graph):
                        key = (call.fn, trip)
                        if key in seen:
                            continue
                        seen.add(key)
                        out.append(RuleConfig.make(self.name, path=p, output=name,
                                                   fn=call.fn, matmul=trip))
        return out

    def apply(self, plan, catalog, cfg):
        registry = plan.registry.copy()
        fn = registry.get(cfg.get("fn"))
        g = fn.graph
        mm_id = cfg.get("matmul")
        mm = g.node(mm_id)
        users = base.graph_users(g)
        bias = g.node(users[mm_id][0])
        act = g.node(users[bias.id][0])
        fused = Atom("fused_dense", {"w": mm.atom.params["w"],
                                     "b": bias.atom.params["b"],
                                     "act": act.atom.params["fn"]})
        nid = g.fresh_id()
        new_node = MLNode(id=nid, atom=fused, args=mm.args)
        # remove mm/bias, rewire act's node id to fused output
        nodes = []
        for n in g.nodes:
            if n.id in (mm_id, bias.id):
                continue
            if n.id == act.id:
                nodes.append(MLNode(id=act.id, atom=Atom("act", {"fn": "identity"}),
                                    args=(("node", nid),)))
                nodes.insert(len(nodes) - 1, new_node)
                continue
            nodes.append(n)
        g2 = MLGraph(nodes=nodes, out=g.out, n_inputs=g.n_inputs)
        new_name = registry.fresh_name(fn.name + "_fused")
        registry.replace(dataclasses.replace(fn, name=new_name, graph=g2))
        root = _rename_call(plan.root, cfg.get("path"), cfg.get("fn"), new_name)
        return ir.Plan(root, registry, plan.phys)


@register_rule
class UnfuseDense(Rule):
    name = "R4-1-unfuse"
    category = "O4"

    def configs(self, plan, catalog):
        out = []
        seen = set()
        for p in base.all_paths(plan.root):
            n = base.node_at(plan.root, p)
            if not isinstance(n, ir.Project):
                continue
            for name, e in n.outputs:
                for call in base.expr_calls(e):
                    fn = plan.registry.get(call.fn)
                    if fn.graph is None:
                        continue
                    for gn in fn.graph.nodes:
                        if gn.atom.kind == "fused_dense" and (call.fn, gn.id) not in seen:
                            seen.add((call.fn, gn.id))
                            out.append(RuleConfig.make(self.name, path=p,
                                                       fn=call.fn, node=gn.id))
        return out

    def apply(self, plan, catalog, cfg):
        registry = plan.registry.copy()
        fn = registry.get(cfg.get("fn"))
        g = fn.graph
        fd = g.node(cfg.get("node"))
        nid = g.fresh_id()
        mm = MLNode(id=nid, atom=Atom("matmul", {"w": fd.atom.params["w"]}), args=fd.args)
        bi = MLNode(id=nid + 1, atom=Atom("bias", {"b": fd.atom.params["b"]}),
                    args=(("node", nid),))
        ac = MLNode(id=nid + 2, atom=Atom("act", {"fn": fd.atom.params["act"]}),
                    args=(("node", nid + 1),))
        g2 = base.replace_graph_node(g, fd.id, [mm, bi, ac], nid + 2)
        new_name = registry.fresh_name(fn.name + "_unfused")
        registry.replace(dataclasses.replace(fn, name=new_name, graph=g2))
        root = _rename_call(plan.root, cfg.get("path"), cfg.get("fn"), new_name)
        return ir.Plan(root, registry, plan.phys)


@register_rule
class BackendReplace(Rule):
    name = "R4-2"
    category = "O4"

    def configs(self, plan, catalog):
        out = []
        seen = set()
        for p in base.all_paths(plan.root):
            n = base.node_at(plan.root, p)
            if isinstance(n, (ir.BlockedMatmul, ir.ForestRelational)):
                pc = plan.phys_for(n)
                for be in ("jnp", "pallas"):
                    if be != pc.backend:
                        out.append(RuleConfig.make(self.name, path=p, kind="node",
                                                   backend=be))
                if pc.mode == "relational":
                    out.append(RuleConfig.make(self.name, path=p, kind="mode",
                                               backend="fused"))
            if isinstance(n, ir.Project):
                for name, e in n.outputs:
                    for call in base.expr_calls(e):
                        fn = plan.registry.get(call.fn)
                        if fn.graph is None:
                            continue
                        for gn in fn.graph.nodes:
                            if gn.atom.kind in ("fused_dense", "forest"):
                                be = "pallas" if gn.atom.backend == "jnp" else "jnp"
                                key = (call.fn, gn.id, be)
                                if key in seen:
                                    continue
                                seen.add(key)
                                out.append(RuleConfig.make(self.name, path=p,
                                                           kind="atom", fn=call.fn,
                                                           node=gn.id, backend=be))
        return out

    def apply(self, plan, catalog, cfg):
        if cfg.get("kind") == "node":
            n = base.node_at(plan.root, cfg.get("path"))
            new_cfg = dataclasses.replace(plan.phys_for(n),
                                          backend=cfg.get("backend"))
            return plan.with_phys(n.uid, new_cfg)
        if cfg.get("kind") == "mode":
            n = base.node_at(plan.root, cfg.get("path"))
            new_cfg = dataclasses.replace(plan.phys_for(n), mode="fused")
            return plan.with_phys(n.uid, new_cfg)
        registry = plan.registry.copy()
        fn = registry.get(cfg.get("fn"))
        g = fn.graph
        nodes = []
        for n in g.nodes:
            if n.id == cfg.get("node"):
                atom = dataclasses.replace(n.atom, backend=cfg.get("backend"))
                nodes.append(MLNode(id=n.id, atom=atom, args=n.args))
            else:
                nodes.append(n)
        g2 = MLGraph(nodes=nodes, out=g.out, n_inputs=g.n_inputs)
        new_name = registry.fresh_name(fn.name + "_be")
        registry.replace(dataclasses.replace(fn, name=new_name, graph=g2))
        root = _rename_call(plan.root, cfg.get("path"), cfg.get("fn"), new_name)
        return ir.Plan(root, registry, plan.phys)


@register_rule
class ConstantFold(Rule):
    name = "R4-4"
    category = "O4"

    def configs(self, plan, catalog):
        out = []
        for p in base.all_paths(plan.root):
            n = base.node_at(plan.root, p)
            exprs = []
            if isinstance(n, ir.Filter):
                exprs = [n.pred]
            elif isinstance(n, ir.Project):
                exprs = [e for _, e in n.outputs]
            if any(_foldable(e) for e in exprs):
                out.append(RuleConfig.make(self.name, path=p))
        return out

    def apply(self, plan, catalog, cfg):
        n = base.node_at(plan.root, cfg.get("path"))
        if isinstance(n, ir.Filter):
            new = dataclasses.replace(n, pred=_fold(n.pred))
        else:
            new = dataclasses.replace(
                n, outputs=tuple((nm, _fold(e)) for nm, e in n.outputs))
        return plan.replace_root(base.replace_at(plan.root, cfg.get("path"), new))


def _fusable_triples(g: MLGraph):
    users = base.graph_users(g)
    by_id = {n.id: n for n in g.nodes}
    for n in g.nodes:
        if n.atom.kind != "matmul":
            continue
        if len(users[n.id]) != 1:
            continue
        b = by_id[users[n.id][0]]
        if b.atom.kind != "bias" or len(users[b.id]) != 1:
            continue
        a = by_id[users[b.id][0]]
        if a.atom.kind != "act":
            continue
        yield n.id


def _rename_call(root, path, old_fn, new_fn):
    node = base.node_at(root, path)

    def rn(e: ir.Expr) -> ir.Expr:
        if isinstance(e, ir.Call):
            args = tuple(rn(a) for a in e.args)
            return ir.Call(new_fn if e.fn == old_fn else e.fn, args)
        if isinstance(e, ir.BinOp):
            return ir.BinOp(e.op, rn(e.a), rn(e.b))
        if isinstance(e, ir.Cmp):
            return ir.Cmp(e.op, rn(e.a), rn(e.b))
        if isinstance(e, ir.BoolOp):
            return ir.BoolOp(e.op, tuple(rn(a) for a in e.args))
        if isinstance(e, ir.IsIn):
            return ir.IsIn(rn(e.a), e.values)
        if isinstance(e, ir.IfExpr):
            return ir.IfExpr(rn(e.cond), rn(e.t), rn(e.f))
        return e

    if isinstance(node, ir.Project):
        new = dataclasses.replace(
            node, outputs=tuple((nm, rn(e)) for nm, e in node.outputs))
    elif isinstance(node, ir.Filter):
        new = dataclasses.replace(node, pred=rn(node.pred))
    else:
        raise TypeError(type(node))
    return base.replace_at(root, path, new)


def _foldable(e: ir.Expr) -> bool:
    if isinstance(e, (ir.BinOp, ir.Cmp)) and isinstance(e.a, ir.Const) \
            and isinstance(e.b, ir.Const):
        return True
    return any(_foldable(c) for c in e.children())


def _fold(e: ir.Expr) -> ir.Expr:
    if isinstance(e, ir.BinOp):
        a, b = _fold(e.a), _fold(e.b)
        if isinstance(a, ir.Const) and isinstance(b, ir.Const):
            va, vb = a.value, b.value
            return ir.Const({"+": va + vb, "-": va - vb, "*": va * vb,
                             "/": va / (vb if vb else 1e-9)}[e.op])
        return ir.BinOp(e.op, a, b)
    if isinstance(e, ir.Cmp):
        a, b = _fold(e.a), _fold(e.b)
        if isinstance(a, ir.Const) and isinstance(b, ir.Const):
            va, vb = a.value, b.value
            return ir.Const(float({"<": va < vb, ">": va > vb, "<=": va <= vb,
                                   ">=": va >= vb, "==": va == vb,
                                   "!=": va != vb}[e.op]))
        return ir.Cmp(e.op, a, b)
    if isinstance(e, ir.BoolOp):
        return ir.BoolOp(e.op, tuple(_fold(a) for a in e.args))
    if isinstance(e, ir.IsIn):
        return ir.IsIn(_fold(e.a), e.values)
    if isinstance(e, ir.IfExpr):
        return ir.IfExpr(_fold(e.cond), _fold(e.t), _fold(e.f))
    if isinstance(e, ir.Call):
        return ir.Call(e.fn, tuple(_fold(a) for a in e.args))
    return e
