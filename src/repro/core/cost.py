"""Analytic cost model for physical plans.

Costs each node by FLOPs + bytes moved against a device profile, walking the
plan with the cardinality/capacity estimates from ir.infer. On TPU the
*capacity* (static shape) drives cost, not the live-row count — which is
exactly why compaction after selective filters matters (DESIGN.md Sec. 2).

This model is the MCTS reward oracle for fast/deterministic paths; the
learned latency predictor (core.embedding) plays the paper's Query2Vec role
and is trained against measured wall-clock of compiled plans.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Mapping, Optional

from repro.core import ir
from repro.mlfuncs.registry import Registry

PhysMap = Optional[Mapping[str, ir.PhysConfig]]


@dataclasses.dataclass
class DeviceProfile:
    name: str = "tpu-v5e"
    peak_flops: float = 197e12      # bf16 FLOP/s
    hbm_bw: float = 819e9           # bytes/s
    vmem_bw: float = 4.0e12         # effective on-chip bandwidth for fused ops
    elem_bytes: int = 4
    # fixed overhead per relational operator (dispatch/fusion boundary)
    op_overhead_s: float = 2e-6


CPU_PROFILE = DeviceProfile(name="cpu", peak_flops=2e11, hbm_bw=3e10,
                            vmem_bw=2e11, op_overhead_s=5e-6)


def _row_bytes(schema: Dict[str, int], profile: DeviceProfile) -> float:
    return sum(max(d, 1) for d in schema.values()) * profile.elem_bytes


def _time(flops: float, bytes_: float, profile: DeviceProfile) -> float:
    return max(flops / profile.peak_flops, bytes_ / profile.hbm_bw) + profile.op_overhead_s


def node_cost(node: ir.RelNode, registry: Registry, catalog: ir.Catalog,
              profile: DeviceProfile, phys: PhysMap = None) -> float:
    """Recursive total plan cost in seconds (analytic)."""
    total = sum(node_cost(c, registry, catalog, profile, phys)
                for c in node.children())
    total += _local_cost(node, registry, catalog, profile, phys)
    return total


def _local_cost(node: ir.RelNode, registry: Registry, catalog: ir.Catalog,
                profile: DeviceProfile, phys: PhysMap = None) -> float:
    if isinstance(node, ir.Scan):
        return 0.0
    if isinstance(node, ir.Filter):
        ci = ir.infer(node.child, registry, catalog)
        fl = ir.expr_flops(node.pred, ci.schema, registry) * ci.capacity
        by = _row_bytes(ci.schema, profile) * ci.capacity
        return _time(fl, by, profile)
    if isinstance(node, ir.Compact):
        ci = ir.infer(node.child, registry, catalog)
        by = _row_bytes(ci.schema, profile) * (ci.capacity + node.capacity)
        return _time(ci.capacity * 8.0, by, profile)  # sort + gather
    if isinstance(node, ir.Project):
        ci = ir.infer(node.child, registry, catalog)
        fl = sum(ir.expr_flops(e, ci.schema, registry) for _, e in node.outputs)
        fl *= ci.capacity
        out = ir.infer(node, registry, catalog)
        by = (_row_bytes(ci.schema, profile) + _row_bytes(out.schema, profile)) * ci.capacity
        # parameter traffic: weights stream from HBM once per call
        pb = 0.0
        for _, e in node.outputs:
            for c in _calls(e):
                pb += registry.get(c.fn).param_bytes()
        return _time(fl, by + pb, profile)
    if isinstance(node, ir.Join):
        li = ir.infer(node.left, registry, catalog)
        ri = ir.infer(node.right, registry, catalog)
        out = ir.infer(node, registry, catalog)
        fl = (li.capacity + ri.capacity) * 32.0  # sort/searchsorted
        by = (_row_bytes(li.schema, profile) * li.capacity
              + _row_bytes(ri.schema, profile) * ri.capacity
              + _row_bytes(out.schema, profile) * out.capacity)
        return _time(fl, by, profile)
    if isinstance(node, ir.CrossJoin):
        out = ir.infer(node, registry, catalog)
        by = 2.0 * _row_bytes(out.schema, profile) * out.capacity
        return _time(out.capacity * 2.0, by, profile)
    if isinstance(node, ir.Aggregate):
        ci = ir.infer(node.child, registry, catalog)
        fl = ci.capacity * (16.0 + 2.0 * len(node.aggs))
        by = _row_bytes(ci.schema, profile) * ci.capacity
        return _time(fl, by, profile)
    if isinstance(node, ir.BlockedMatmul):
        ci = ir.infer(node.child, registry, catalog)
        fn = registry.get(node.fn)
        pc = ir.resolve_phys(node, phys, registry)
        fl = fn.flops_per_row([ci.schema[node.x_col]]) * ci.capacity
        pb = fn.param_bytes()
        xby = max(ci.schema[node.x_col], 1) * profile.elem_bytes * ci.capacity
        if pc.mode == "relational":
            # streamed tile scan: x re-read per tile + per-tile op overhead
            xby *= pc.n_tiles
            extra = pc.n_tiles * profile.op_overhead_s
        else:
            extra = 0.0
        bw = profile.vmem_bw if pc.backend == "pallas" else profile.hbm_bw
        t = max(fl / profile.peak_flops, (pb + 2 * xby) / bw)
        return t + profile.op_overhead_s + extra
    if isinstance(node, ir.ForestRelational):
        ci = ir.infer(node.child, registry, catalog)
        fn = registry.get(node.fn)
        pc = ir.resolve_phys(node, phys, registry)
        fl = fn.flops_per_row([ci.schema[node.x_col]]) * ci.capacity
        pb = fn.param_bytes()
        xby = max(ci.schema[node.x_col], 1) * profile.elem_bytes * ci.capacity
        if pc.mode == "relational":
            p = fn.graph.nodes[0].atom.params
            xby *= p["feat"].shape[0]
        bw = profile.vmem_bw if pc.backend == "pallas" else profile.hbm_bw
        return max(fl / profile.peak_flops, (pb + xby) / bw) + profile.op_overhead_s
    raise TypeError(type(node))


def _calls(e: ir.Expr):
    if isinstance(e, ir.Call):
        yield e
    for c in e.children():
        yield from _calls(c)


# ---------------------------------------------------------------------------
# memory (peak working set) — the paper's OOM axis (Table I, Fig. 6)
# ---------------------------------------------------------------------------

def node_mem(node: ir.RelNode, registry: Registry, catalog: ir.Catalog,
             profile: DeviceProfile, phys: PhysMap = None) -> float:
    """Peak bytes over the plan (max across operators)."""
    peak = max((node_mem(c, registry, catalog, profile, phys)
                for c in node.children()), default=0.0)
    return max(peak, _local_mem(node, registry, catalog, profile, phys))


def _local_mem(node, registry, catalog, profile, phys=None):
    if isinstance(node, ir.Scan):
        st = catalog.stats[node.table]
        return _row_bytes({c: s.dim for c, s in st.columns.items()}, profile) * st.capacity
    out = ir.infer(node, registry, catalog)
    base = _row_bytes(out.schema, profile) * out.capacity
    if isinstance(node, ir.Project):
        pb = 0.0
        for _, e in node.outputs:
            for c in _calls(e):
                pb += registry.get(c.fn).param_bytes()
        return base + pb
    if isinstance(node, ir.BlockedMatmul):
        fn = registry.get(node.fn)
        # streamed: only one weight tile resident at a time
        return base + fn.param_bytes() / max(ir.resolve_phys(node, phys, registry).n_tiles, 1)
    if isinstance(node, ir.ForestRelational):
        fn = registry.get(node.fn)
        p = fn.graph.nodes[0].atom.params
        n_trees = max(int(p["feat"].shape[0]), 1)  # per-tree streaming
        return base + fn.param_bytes() / n_trees
    return base


def plan_peak_memory(plan: ir.Plan, catalog: ir.Catalog,
                     profile: DeviceProfile | None = None) -> float:
    profile = profile or DeviceProfile()
    return node_mem(plan.root, plan.registry, catalog, profile, plan.phys)


def plan_cost(plan: ir.Plan, catalog: ir.Catalog,
              profile: DeviceProfile | None = None,
              memory_budget: float | None = None) -> float:
    """Analytic plan latency; plans whose working set exceeds the memory
    budget pay a paging/OOM penalty (mirrors the paper's OOM failures)."""
    profile = profile or DeviceProfile()
    t = node_cost(plan.root, plan.registry, catalog, profile, plan.phys)
    if memory_budget is not None:
        peak = plan_peak_memory(plan, catalog, profile)
        if peak > memory_budget:
            t *= 1.0 + 20.0 * (peak / memory_budget - 1.0)
    return t
