"""Analytic cost model — the single cost oracle of the stack.

Every component that needs a notion of "cheap" routes through ``plan_cost``:
the MCTS reward oracle (``planner.analytic_cost_fn`` / ``mcts.VanillaMCTS``),
costed lowering (``core.costed_lowering`` scores physical candidates), the
serving tier's batch-realization choice (``batched_plan_cost``), and the
online feedback calibration (``fit_profile`` refits a ``DeviceProfile``
against measured dispatch latencies). ``plan_cost`` accepts both the logical
``ir.Plan`` and the physical ``physical.PhysicalPlan``; both walks share the
same per-operator ``OpCost`` kernels, so there is exactly one set of cost
formulas (a tree-order-lowered physical plan costs bit-identically to its
logical tree).

Costs each operator by FLOPs + bytes moved against a device profile, using
capacity (static shape) rather than live-row counts — on TPU the *capacity*
drives cost, which is exactly why compaction after selective filters matters
(DESIGN.md Sec. 2). The learned latency predictor (core.embedding) plays the
paper's Query2Vec role and is trained against measured wall-clock of
compiled plans; it is deliberately a separate estimator.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.core import ir
from repro.mlfuncs.registry import Registry

PhysMap = Optional[Mapping[str, ir.PhysConfig]]


@dataclasses.dataclass
class DeviceProfile:
    name: str = "tpu-v5e"
    peak_flops: float = 197e12      # bf16 FLOP/s
    hbm_bw: float = 819e9           # bytes/s
    vmem_bw: float = 4.0e12         # effective on-chip bandwidth for fused ops
    elem_bytes: int = 4
    # fixed overhead per relational operator (dispatch/fusion boundary)
    op_overhead_s: float = 2e-6
    # per-shard fan-in/out overhead of a multi-device (sharded) dispatch and
    # per-shard launch cost of one in-plan collective (allgather/psum).
    # Every backend prior is non-zero: a 0.0 default would price all
    # collectives as free and silently bias every sharded-vs-local decision
    # toward sharding; serving/feedback.py calibrates it online alongside
    # peak_flops/hbm_bw/op_overhead_s when sharded traffic exists.
    collective_overhead_s: float = 1e-6
    # per-device working-set budget in bytes (None = unlimited): costed
    # lowering hard-rejects candidates whose phys_peak_memory exceeds it,
    # and plan_cost applies its paging penalty. The serving tier installs
    # its real budget here (QueryServer(memory_budget=...)).
    memory_budget: Optional[float] = None
    # whether the pallas kernel realizations are executable on this device
    supports_pallas: bool = True

    def signature(self) -> str:
        """Calibratable-field token: anything the feedback loop can move.
        Two profiles with equal signatures make identical lowering
        decisions. (PlanCache invalidates its decision memos via
        ``profile_epoch``, bumped by ``recalibrate()`` — mutating a
        profile's fields in place does NOT re-derive decisions.)"""
        mb = "-" if self.memory_budget is None else f"{self.memory_budget:.4e}"
        return (f"{self.name}:pf={self.peak_flops:.4e},bw={self.hbm_bw:.4e},"
                f"vb={self.vmem_bw:.4e},ov={self.op_overhead_s:.4e},"
                f"co={self.collective_overhead_s:.4e},mb={mb}")

    @classmethod
    def detect(cls) -> "DeviceProfile":
        """A fresh profile for the host's JAX backend.

        Returns a *copy* (profiles are mutable calibration targets; the
        module singletons below are priors, never calibrated in place).
        """
        import jax
        backend = jax.default_backend()
        if backend == "tpu":
            prior = TPU_PROFILE
        elif backend in ("gpu", "cuda", "rocm"):
            prior = GPU_PROFILE
        else:
            prior = CPU_PROFILE
        return dataclasses.replace(prior)


# collective priors: per-shard launch latency of one ICI/NVLink collective
# on real accelerators; the "devices" of a forced CPU host mesh share one
# address space, so a collective there is a plain memcpy whose *volume*
# already rides data_bytes — only a tiny per-launch latency remains
TPU_PROFILE = DeviceProfile(collective_overhead_s=1e-6)

GPU_PROFILE = DeviceProfile(name="gpu-a100", peak_flops=312e12,
                            hbm_bw=1.55e12, vmem_bw=5.0e12,
                            op_overhead_s=3e-6, collective_overhead_s=2e-6,
                            supports_pallas=False)

CPU_PROFILE = DeviceProfile(name="cpu", peak_flops=2e11, hbm_bw=3e10,
                            vmem_bw=2e11, op_overhead_s=5e-6,
                            collective_overhead_s=2e-7,
                            supports_pallas=False)

_DETECTED: Optional[DeviceProfile] = None


def default_profile() -> DeviceProfile:
    """Process-wide detected profile (lazy, computed once). Default for
    every ``plan_cost`` entry that is not handed an explicit profile."""
    global _DETECTED
    if _DETECTED is None:
        _DETECTED = DeviceProfile.detect()
    return _DETECTED


# ---------------------------------------------------------------------------
# per-operator cost kernels
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class OpCost:
    """One physical operator's resource footprint, device-independent.

    ``data_bytes`` scale with the data/batch axis (a B-query vmapped
    dispatch moves B x data_bytes); ``param_bytes`` are weight traffic,
    streamed once per dispatch and replicated across shards. ``n_ops``
    counts dispatch/fusion-boundary overhead units (``op_overhead_s``);
    ``n_coll`` counts per-shard collective launches
    (``collective_overhead_s`` — a ``ways``-way allgather/psum pays
    ``ways`` of them, its exchange volume rides ``data_bytes``).
    """
    label: str
    flops: float = 0.0
    data_bytes: float = 0.0
    param_bytes: float = 0.0
    bw: str = "hbm"              # 'hbm' | 'vmem' (pallas-fused operators)
    n_ops: int = 1
    n_coll: int = 0


def op_time(oc: OpCost, profile: DeviceProfile, data_scale: float = 1.0) -> float:
    """Roofline time of one operator: max(compute, traffic) + overhead."""
    bw = profile.vmem_bw if oc.bw == "vmem" else profile.hbm_bw
    return (max(oc.flops * data_scale / profile.peak_flops,
                (oc.data_bytes * data_scale + oc.param_bytes) / bw)
            + oc.n_ops * profile.op_overhead_s
            + oc.n_coll * profile.collective_overhead_s)


def _row_bytes(schema: Dict[str, int], profile: DeviceProfile) -> float:
    return sum(max(d, 1) for d in schema.values()) * profile.elem_bytes


def _filter_cost(pred_flops: float, schema, capacity, profile) -> OpCost:
    return OpCost("filter", flops=pred_flops * capacity,
                  data_bytes=_row_bytes(schema, profile) * capacity)


def _compact_cost(schema, cap_in, cap_out, profile) -> OpCost:
    return OpCost("compact", flops=cap_in * 8.0,  # sort + gather
                  data_bytes=_row_bytes(schema, profile) * (cap_in + cap_out))


def _project_cost(expr_flops: float, in_schema, out_schema, param_bytes,
                  capacity, profile) -> OpCost:
    by = (_row_bytes(in_schema, profile)
          + _row_bytes(out_schema, profile)) * capacity
    return OpCost("project", flops=expr_flops * capacity, data_bytes=by,
                  param_bytes=param_bytes)


def _join_cost(l_schema, l_cap, r_schema, r_cap, out_schema, out_cap,
               profile) -> OpCost:
    fl = (l_cap + r_cap) * 32.0  # sort/searchsorted
    by = (_row_bytes(l_schema, profile) * l_cap
          + _row_bytes(r_schema, profile) * r_cap
          + _row_bytes(out_schema, profile) * out_cap)
    return OpCost("join", flops=fl, data_bytes=by)


def _crossjoin_cost(out_schema, out_cap, profile) -> OpCost:
    return OpCost("crossjoin", flops=out_cap * 2.0,
                  data_bytes=2.0 * _row_bytes(out_schema, profile) * out_cap)


def _aggregate_cost(schema, capacity, n_aggs, profile) -> OpCost:
    return OpCost("aggregate", flops=capacity * (16.0 + 2.0 * n_aggs),
                  data_bytes=_row_bytes(schema, profile) * capacity)


def _matmul_cost(fn, x_dim, capacity, cfg: ir.PhysConfig, profile) -> OpCost:
    fl = fn.flops_per_row([x_dim]) * capacity
    pb = fn.param_bytes()
    xby = max(x_dim, 1) * profile.elem_bytes * capacity
    extra = 0
    if cfg.mode == "relational":
        # streamed tile scan: x re-read per tile + per-tile op overhead
        xby *= cfg.n_tiles
        extra = cfg.n_tiles
    return OpCost("matmul", flops=fl, data_bytes=2 * xby, param_bytes=pb,
                  bw="vmem" if cfg.backend == "pallas" else "hbm",
                  n_ops=1 + extra)


def _repartition_cost(node, schema, in_cap, profile) -> OpCost:
    """Partition-boundary cost: local copies for slice/bucket, exchange
    volume + per-shard collective launches for allgather/combine."""
    rb = _row_bytes(schema, profile)
    if node.op == "slice":
        return OpCost("repart_slice", data_bytes=2.0 * rb * node.out_capacity)
    if node.op == "allgather":
        # each device receives and writes the full reassembled table
        return OpCost("repart_allgather",
                      data_bytes=2.0 * rb * node.out_capacity,
                      n_coll=node.ways)
    if node.op == "bucket":
        # hash + compare on the key column, mask write
        return OpCost("repart_bucket", flops=4.0 * in_cap,
                      data_bytes=3.0 * profile.elem_bytes * in_cap)
    if node.op == "combine":
        # zero-and-psum of every column: full-table exchange per device
        return OpCost("repart_combine",
                      flops=float(max(len(schema), 1)) * in_cap,
                      data_bytes=2.0 * rb * node.out_capacity,
                      n_coll=node.ways)
    raise ValueError(f"unknown repartition op {node.op!r}")


def _forest_cost(fn, x_dim, capacity, cfg: ir.PhysConfig, profile) -> OpCost:
    fl = fn.flops_per_row([x_dim]) * capacity
    pb = fn.param_bytes()
    xby = max(x_dim, 1) * profile.elem_bytes * capacity
    if cfg.mode == "relational":
        p = fn.graph.nodes[0].atom.params
        xby *= p["feat"].shape[0]  # x re-read once per streamed tree
    return OpCost("forest", flops=fl, data_bytes=xby, param_bytes=pb,
                  bw="vmem" if cfg.backend == "pallas" else "hbm")


# ---------------------------------------------------------------------------
# logical-plan walk
# ---------------------------------------------------------------------------

def node_cost(node: ir.RelNode, registry: Registry, catalog: ir.Catalog,
              profile: DeviceProfile, phys: PhysMap = None) -> float:
    """Recursive total plan cost in seconds (analytic)."""
    total = sum(node_cost(c, registry, catalog, profile, phys)
                for c in node.children())
    oc = _node_op_cost(node, registry, catalog, profile, phys)
    if oc is not None:
        total += op_time(oc, profile)
    return total


def _node_op_cost(node: ir.RelNode, registry: Registry, catalog: ir.Catalog,
                  profile: DeviceProfile, phys: PhysMap = None
                  ) -> Optional[OpCost]:
    if isinstance(node, ir.Scan):
        return None
    if isinstance(node, ir.Filter):
        ci = ir.infer(node.child, registry, catalog)
        return _filter_cost(ir.expr_flops(node.pred, ci.schema, registry),
                            ci.schema, ci.capacity, profile)
    if isinstance(node, ir.Compact):
        ci = ir.infer(node.child, registry, catalog)
        return _compact_cost(ci.schema, ci.capacity, node.capacity, profile)
    if isinstance(node, ir.Project):
        ci = ir.infer(node.child, registry, catalog)
        fl = sum(ir.expr_flops(e, ci.schema, registry) for _, e in node.outputs)
        out = ir.infer(node, registry, catalog)
        # parameter traffic: weights stream from HBM once per call
        pb = 0.0
        for _, e in node.outputs:
            for c in _calls(e):
                pb += registry.get(c.fn).param_bytes()
        return _project_cost(fl, ci.schema, out.schema, pb, ci.capacity,
                             profile)
    if isinstance(node, ir.Join):
        li = ir.infer(node.left, registry, catalog)
        ri = ir.infer(node.right, registry, catalog)
        out = ir.infer(node, registry, catalog)
        return _join_cost(li.schema, li.capacity, ri.schema, ri.capacity,
                          out.schema, out.capacity, profile)
    if isinstance(node, ir.CrossJoin):
        out = ir.infer(node, registry, catalog)
        return _crossjoin_cost(out.schema, out.capacity, profile)
    if isinstance(node, ir.Aggregate):
        ci = ir.infer(node.child, registry, catalog)
        return _aggregate_cost(ci.schema, ci.capacity, len(node.aggs), profile)
    if isinstance(node, ir.BlockedMatmul):
        ci = ir.infer(node.child, registry, catalog)
        return _matmul_cost(registry.get(node.fn), ci.schema[node.x_col],
                            ci.capacity, ir.resolve_phys(node, phys, registry),
                            profile)
    if isinstance(node, ir.ForestRelational):
        ci = ir.infer(node.child, registry, catalog)
        return _forest_cost(registry.get(node.fn), ci.schema[node.x_col],
                            ci.capacity, ir.resolve_phys(node, phys, registry),
                            profile)
    raise TypeError(type(node))


def _calls(e: ir.Expr):
    if isinstance(e, ir.Call):
        yield e
    for c in e.children():
        yield from _calls(c)


# ---------------------------------------------------------------------------
# physical-plan walk (costed lowering's candidate scorer)
# ---------------------------------------------------------------------------

def _stage_info(stage, schema: Dict[str, int], capacity: int,
                registry: Registry) -> Tuple[Dict[str, int], int]:
    """Schema/capacity after one pipeline stage (exact, statically known)."""
    from repro.core import physical as ph
    if isinstance(stage, ph.FilterStage):
        return schema, capacity
    if isinstance(stage, ph.CompactStage):
        return schema, stage.capacity
    if isinstance(stage, ph.ProjectStage):
        out = (dict(schema) if stage.keep is None
               else {k: schema[k] for k in stage.keep})
        for name, e in stage.outputs:
            out[name] = ir.expr_dim(e, schema, registry)
        return out, capacity
    raise TypeError(type(stage))


def _derive_info(node, registry: Registry, catalog: ir.Catalog,
                 child_infos) -> Tuple[Dict[str, int], int]:
    """(schema, capacity) of a physical node's output from its children's
    already-computed infos — single level, so walks that visit each node
    once stay linear in plan size."""
    from repro.core import physical as ph
    if isinstance(node, ph.PScan):
        st = catalog.stats[node.table]
        return {c: s.dim for c, s in st.columns.items()}, st.capacity
    if isinstance(node, ph.PPipeline):
        schema, cap = child_infos[0]
        for stage in node.stages:
            schema, cap = _stage_info(stage, schema, cap, registry)
        return schema, cap
    if isinstance(node, ph.PJoin):
        (ls, lc), (rs, _) = child_infos
        schema = dict(ls)
        for c, d in rs.items():
            out = node.rprefix + c
            if out == node.left_key and c == node.right_key:
                continue
            schema[out] = d
        return schema, lc
    if isinstance(node, ph.PCrossJoin):
        (ls, lc), (rs, rc) = child_infos
        schema = {node.aprefix + c: d for c, d in ls.items()}
        schema.update({node.bprefix + c: d for c, d in rs.items()})
        return schema, lc * rc
    if isinstance(node, ph.PAggregate):
        cs, _ = child_infos[0]
        schema = {node.key: 0}
        for out, (kind, in_col) in node.aggs:
            schema[out] = 0 if kind == "count" else cs.get(in_col, 0)
        return schema, node.num_groups
    if isinstance(node, ph.PBlockedMatmul):
        cs, cc = child_infos[0]
        schema = dict(cs) if node.keep is None else {k: cs[k] for k in node.keep}
        schema[node.out_col] = registry.get(node.fn).out_dim([cs[node.x_col]])
        return schema, cc
    if isinstance(node, ph.PForestRelational):
        cs, cc = child_infos[0]
        schema = dict(cs) if node.keep is None else {k: cs[k] for k in node.keep}
        schema[node.out_col] = 0
        return schema, cc
    if isinstance(node, ph.PRepartition):
        cs, cc = child_infos[0]
        if node.op in ("slice", "allgather"):
            # the walk downstream of a slice sees the per-device block
            # capacity, which is what makes the physical walk price (and
            # phys_peak_memory bound) *per-device* work on partitioned plans
            return cs, node.out_capacity
        return cs, cc  # bucket/combine: capacity unchanged
    raise TypeError(type(node))


def phys_node_info(node, registry: Registry, catalog: ir.Catalog
                   ) -> Tuple[Dict[str, int], int]:
    """(schema, capacity) of a physical node's output — the physical mirror
    of ``ir.infer`` without row estimates (cost is capacity-driven)."""
    return _derive_info(node, registry, catalog,
                        tuple(phys_node_info(c, registry, catalog)
                              for c in node.children()))


def phys_op_costs(pplan, catalog: ir.Catalog,
                  profile: DeviceProfile) -> List[OpCost]:
    """Per-operator OpCosts of a physical plan, through the same kernels as
    the logical walk (tree-order lowering costs identically either way)."""
    from repro.core import physical as ph
    registry = pplan.registry
    out: List[OpCost] = []

    def visit(node) -> Tuple[Dict[str, int], int]:
        child_infos = tuple(visit(c) for c in node.children())
        if isinstance(node, ph.PPipeline):
            schema, cap = child_infos[0]
            for stage in node.stages:
                nxt = _stage_info(stage, schema, cap, registry)
                if isinstance(stage, ph.FilterStage):
                    out.append(_filter_cost(
                        ir.expr_flops(stage.pred, schema, registry),
                        schema, cap, profile))
                elif isinstance(stage, ph.CompactStage):
                    out.append(_compact_cost(schema, cap, stage.capacity,
                                             profile))
                elif isinstance(stage, ph.ProjectStage):
                    fl = sum(ir.expr_flops(e, schema, registry)
                             for _, e in stage.outputs)
                    pb = 0.0
                    for _, e in stage.outputs:
                        for c in _calls(e):
                            pb += registry.get(c.fn).param_bytes()
                    out.append(_project_cost(fl, schema, nxt[0], pb, cap,
                                             profile))
                schema, cap = nxt
            return schema, cap
        info = _derive_info(node, registry, catalog, child_infos)
        if isinstance(node, ph.PJoin):
            (ls, lc), (rs, rc) = child_infos
            out.append(_join_cost(ls, lc, rs, rc, info[0], info[1], profile))
        elif isinstance(node, ph.PCrossJoin):
            out.append(_crossjoin_cost(info[0], info[1], profile))
        elif isinstance(node, ph.PAggregate):
            cs, cc = child_infos[0]
            out.append(_aggregate_cost(cs, cc, len(node.aggs), profile))
        elif isinstance(node, ph.PBlockedMatmul):
            cs, cc = child_infos[0]
            cfg = ir.PhysConfig(mode=node.mode, backend=node.backend,
                                n_tiles=node.n_tiles)
            out.append(_matmul_cost(registry.get(node.fn), cs[node.x_col],
                                    cc, cfg, profile))
        elif isinstance(node, ph.PForestRelational):
            cs, cc = child_infos[0]
            cfg = ir.PhysConfig(mode=node.mode, backend=node.backend)
            out.append(_forest_cost(registry.get(node.fn), cs[node.x_col],
                                    cc, cfg, profile))
        elif isinstance(node, ph.PRepartition):
            cs, cc = child_infos[0]
            out.append(_repartition_cost(node, cs, cc, profile))
        elif not isinstance(node, ph.PScan):
            raise TypeError(type(node))
        return info

    visit(pplan.root)
    return out


def phys_peak_memory(pplan, catalog: ir.Catalog,
                     profile: DeviceProfile) -> float:
    """Peak working set of a physical plan (max across operators), the
    physical mirror of ``node_mem``."""
    from repro.core import physical as ph
    registry = pplan.registry
    peak = 0.0

    def base(schema, cap) -> float:
        return _row_bytes(schema, profile) * cap

    def visit(node) -> Tuple[Dict[str, int], int]:
        nonlocal peak
        child_infos = tuple(visit(c) for c in node.children())
        if isinstance(node, ph.PScan):
            schema, cap = _derive_info(node, registry, catalog, child_infos)
            peak = max(peak, base(schema, cap))
            return schema, cap
        if isinstance(node, ph.PPipeline):
            schema, cap = child_infos[0]
            for stage in node.stages:
                schema, cap = _stage_info(stage, schema, cap, registry)
                m = base(schema, cap)
                if isinstance(stage, ph.ProjectStage):
                    for _, e in stage.outputs:
                        for c in _calls(e):
                            m += registry.get(c.fn).param_bytes()
                peak = max(peak, m)
            return schema, cap
        schema, cap = _derive_info(node, registry, catalog, child_infos)
        m = base(schema, cap)
        if isinstance(node, ph.PBlockedMatmul):
            fn = registry.get(node.fn)
            # streamed: only one weight tile resident at a time
            m += fn.param_bytes() / max(node.n_tiles, 1)
        elif isinstance(node, ph.PForestRelational):
            fn = registry.get(node.fn)
            p = fn.graph.nodes[0].atom.params
            m += fn.param_bytes() / max(int(p["feat"].shape[0]), 1)
        elif isinstance(node, ph.PRepartition) and node.op == "allgather":
            # the gather target holds the padded concatenation of every
            # device's block (in_capacity = per-device block) briefly
            m = base(schema, node.in_capacity * node.ways)
        peak = max(peak, m)
        return schema, cap

    visit(pplan.root)
    return peak


# ---------------------------------------------------------------------------
# memory (peak working set) — the paper's OOM axis (Table I, Fig. 6)
# ---------------------------------------------------------------------------

def node_mem(node: ir.RelNode, registry: Registry, catalog: ir.Catalog,
             profile: DeviceProfile, phys: PhysMap = None) -> float:
    """Peak bytes over the plan (max across operators)."""
    peak = max((node_mem(c, registry, catalog, profile, phys)
                for c in node.children()), default=0.0)
    return max(peak, _local_mem(node, registry, catalog, profile, phys))


def _local_mem(node, registry, catalog, profile, phys=None):
    if isinstance(node, ir.Scan):
        st = catalog.stats[node.table]
        return _row_bytes({c: s.dim for c, s in st.columns.items()}, profile) * st.capacity
    out = ir.infer(node, registry, catalog)
    base = _row_bytes(out.schema, profile) * out.capacity
    if isinstance(node, ir.Project):
        pb = 0.0
        for _, e in node.outputs:
            for c in _calls(e):
                pb += registry.get(c.fn).param_bytes()
        return base + pb
    if isinstance(node, ir.BlockedMatmul):
        fn = registry.get(node.fn)
        # streamed: only one weight tile resident at a time
        return base + fn.param_bytes() / max(ir.resolve_phys(node, phys, registry).n_tiles, 1)
    if isinstance(node, ir.ForestRelational):
        fn = registry.get(node.fn)
        p = fn.graph.nodes[0].atom.params
        n_trees = max(int(p["feat"].shape[0]), 1)  # per-tree streaming
        return base + fn.param_bytes() / n_trees
    return base


def plan_peak_memory(plan, catalog: ir.Catalog,
                     profile: DeviceProfile | None = None) -> float:
    from repro.core import physical as ph
    profile = profile or default_profile()
    if isinstance(plan, ph.PhysicalPlan):
        return phys_peak_memory(plan, catalog, profile)
    return node_mem(plan.root, plan.registry, catalog, profile, plan.phys)


# ---------------------------------------------------------------------------
# the single entry point
# ---------------------------------------------------------------------------

def plan_cost(plan, catalog: ir.Catalog,
              profile: DeviceProfile | None = None,
              memory_budget: float | None = None) -> float:
    """Analytic plan latency — logical ``ir.Plan`` or physical
    ``PhysicalPlan`` alike; plans whose working set exceeds the memory
    budget pay a paging/OOM penalty (mirrors the paper's OOM failures).
    ``memory_budget`` defaults to the profile's own per-device budget; a
    non-finite budget is explicitly unlimited (callers that already
    checked the peak themselves — costed lowering's hard gate — pass
    ``inf`` to skip the redundant peak walk)."""
    from repro.core import physical as ph
    profile = profile or default_profile()
    if memory_budget is None:
        memory_budget = profile.memory_budget
    if isinstance(plan, ph.PhysicalPlan):
        t = sum(op_time(oc, profile)
                for oc in phys_op_costs(plan, catalog, profile))
    else:
        t = node_cost(plan.root, plan.registry, catalog, profile, plan.phys)
    if memory_budget is not None and np.isfinite(memory_budget):
        peak = plan_peak_memory(plan, catalog, profile)
        if peak > memory_budget:
            t *= 1.0 + 20.0 * (peak / memory_budget - 1.0)
    return t


@dataclasses.dataclass
class CostBreakdown:
    """Profile-independent resource totals of one plan (plus the seconds the
    given profile predicts) — the calibration features of ``fit_profile``.
    ``hbm_bytes`` are per-query data traffic (they scale with batch
    occupancy); ``param_bytes`` stream once per dispatch. ``n_coll``
    counts per-shard collective launches (in-plan repartition boundaries
    and/or the sharded dispatch's fan-in/out) — the calibration feature of
    ``collective_overhead_s``."""
    flops: float
    hbm_bytes: float
    param_bytes: float
    vmem_bytes: float
    n_ops: int
    seconds: float
    n_coll: float = 0.0

    def scaled(self, occupancy: float) -> "CostBreakdown":
        """The breakdown of one ``occupancy``-query micro-batched dispatch:
        data traffic and FLOPs scale, weights and op count do not."""
        return dataclasses.replace(self, flops=self.flops * occupancy,
                                   hbm_bytes=self.hbm_bytes * occupancy,
                                   vmem_bytes=self.vmem_bytes * occupancy)


def plan_cost_breakdown(plan, catalog: ir.Catalog,
                        profile: DeviceProfile | None = None) -> CostBreakdown:
    from repro.core import physical as ph
    profile = profile or default_profile()
    if isinstance(plan, ph.PhysicalPlan):
        ocs = phys_op_costs(plan, catalog, profile)
    else:
        ocs = [oc for oc in
               (_node_op_cost(n, plan.registry, catalog, profile, plan.phys)
                for n in ir.walk(plan.root)) if oc is not None]
    return CostBreakdown(
        flops=sum(oc.flops for oc in ocs),
        hbm_bytes=sum(oc.data_bytes for oc in ocs if oc.bw == "hbm"),
        param_bytes=sum(oc.param_bytes for oc in ocs if oc.bw == "hbm"),
        vmem_bytes=sum(oc.data_bytes + oc.param_bytes for oc in ocs
                       if oc.bw == "vmem"),
        n_ops=sum(oc.n_ops for oc in ocs),
        seconds=sum(op_time(oc, profile) for oc in ocs),
        n_coll=float(sum(oc.n_coll for oc in ocs)))


def batched_plan_cost(plan, catalog: ir.Catalog, batch_size: int,
                      profile: DeviceProfile | None = None,
                      ways: int = 1) -> float:
    """Predicted latency of one micro-batched dispatch of ``batch_size``
    same-signature queries: data traffic and FLOPs scale with the per-shard
    slice (``batch_size / ways``), weights are replicated (streamed once per
    shard), and a ``ways``-way sharded dispatch pays the profile's collective
    overhead per shard. ``ways=1`` is the vmapped single-device realization;
    the serving tier's vmapped-vs-sharded choice compares the two
    (``costed_lowering.choose_batch_realization``)."""
    from repro.core import physical as ph
    profile = profile or default_profile()
    if isinstance(plan, ph.PhysicalPlan):
        ocs = phys_op_costs(plan, catalog, profile)
    else:
        ocs = [oc for oc in
               (_node_op_cost(n, plan.registry, catalog, profile, plan.phys)
                for n in ir.walk(plan.root)) if oc is not None]
    scale = batch_size / max(ways, 1)
    t = sum(op_time(oc, profile, data_scale=scale) for oc in ocs)
    if ways > 1:
        t += ways * profile.collective_overhead_s
    return t


# ---------------------------------------------------------------------------
# online calibration: measured latencies -> refitted profile
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CalibrationFit:
    profile: DeviceProfile
    n_samples: int
    mape_before: float
    mape_after: float


def _mape(pred: np.ndarray, actual: np.ndarray) -> float:
    actual = np.maximum(actual, 1e-12)
    return float(np.mean(np.abs(pred - actual) / actual))


def fit_profile(samples: Sequence[Tuple[CostBreakdown, float, float]],
                prior: DeviceProfile, l2: float = 0.1,
                max_shift: float = 100.0) -> CalibrationFit:
    """Least-squares refit of (peak_flops, hbm_bw, op_overhead_s,
    collective_overhead_s) from measured latencies.

    ``samples`` are ``(breakdown, measured_seconds, weight)`` triples; the
    linearized prediction ``flops/peak + bytes/bw + n_ops*overhead +
    n_coll*coll_overhead`` is fit in the coefficient space ``x = (1/peak,
    1/bw, overhead, coll_overhead)``. The loss is the weighted *relative*
    squared error (a 200us dispatch mispredicted 2x matters as much as a
    200ms one) plus a log-space ridge toward the prior — multiplicative
    shifts are what calibration corrects, so the penalty is symmetric in
    them, and under-determined directions (serving traffic rarely spans
    enough signatures to identify every coefficient; purely single-device
    traffic has an all-zero ``n_coll`` column) stay at the prior.
    Coefficients live in ``[prior/max_shift, prior*max_shift]`` so a
    pathological batch of measurements cannot turn the oracle nonsensical;
    a coefficient whose prior is zero is pinned (the log-space ridge has no
    anchor there). Solved by deterministic per-coordinate search over a
    refined log grid (4 coefficients; no solver dependency).
    """
    if not samples:
        return CalibrationFit(dataclasses.replace(prior), 0, 0.0, 0.0)
    A = np.array([[b.flops, b.hbm_bytes + b.param_bytes, float(b.n_ops),
                   float(b.n_coll)]
                  for b, _, _ in samples], dtype=np.float64)
    t = np.array([max(m, 1e-9) for _, m, _ in samples], dtype=np.float64)
    w = np.array([max(wt, 1e-12) for _, _, wt in samples], dtype=np.float64)
    x0 = np.array([1.0 / prior.peak_flops, 1.0 / prior.hbm_bw,
                   prior.op_overhead_s, prior.collective_overhead_s],
                  dtype=np.float64)
    active = [k for k in range(4) if x0[k] > 0]
    pred_before = A @ x0
    lo, hi = x0 / max_shift, x0 * max_shift
    w_total = float(np.sum(w))
    log_shift = np.log(max_shift)

    def objective(x: np.ndarray) -> float:
        rel = (A @ x - t) / t
        ridge = float(sum((np.log(x[k] / x0[k]) / log_shift) ** 2
                          for k in active))
        return float(np.sum(w * rel ** 2)) + l2 * w_total * ridge

    x = x0.copy()
    for _ in range(24):
        x_prev = x.copy()
        for k in active:
            span_lo, span_hi = np.log(lo[k]), np.log(hi[k])
            for _refine in range(3):
                grid = np.exp(np.linspace(span_lo, span_hi, 33))
                scores = []
                for g in grid:
                    xk = x.copy()
                    xk[k] = g
                    scores.append(objective(xk))
                bi = int(np.argmin(scores))
                x[k] = grid[bi]
                span_lo = np.log(grid[max(bi - 1, 0)])
                span_hi = np.log(grid[min(bi + 1, len(grid) - 1)])
        if np.max(np.abs(np.log(np.maximum(x, 1e-300)
                                / np.maximum(x_prev, 1e-300)))) < 1e-6:
            break
    fitted = dataclasses.replace(
        prior,
        peak_flops=1.0 / x[0],
        hbm_bw=1.0 / x[1],
        op_overhead_s=float(x[2]),
        collective_overhead_s=float(x[3]),
        name=prior.name if prior.name.endswith("+cal") else prior.name + "+cal")
    return CalibrationFit(profile=fitted, n_samples=len(samples),
                          mape_before=_mape(pred_before, t),
                          mape_after=_mape(A @ x, t))
