"""Weisfeiler-Lehman subtree kernel (paper Alg. 6-9) — used to mine
positive/negative pairs for contrastive training of Model2Vec / Query2Vec.

Node labels are initialized per the paper: Model2Vec labels group atoms by
(kind, FLOPs bucket); Query2Vec labels encode relational-operator identity
(op type + table / predicate / join / aggregation specifics), with ML
expressions labeled through their WL features.
"""
from __future__ import annotations

import math
from collections import Counter
from typing import Dict, List, Tuple

import numpy as np

from repro.core import ir
from repro.mlfuncs.functions import MLGraph


# ---------------------------------------------------------------------------
# generic WL over an adjacency structure
# ---------------------------------------------------------------------------

def wl_features(labels: List[str], children: List[List[int]],
                iters: int = 3) -> Counter:
    """Iteratively hash (label, sorted child labels); count all labels seen
    (Alg. 6)."""
    feats: Counter = Counter(labels)
    cur = list(labels)
    for _ in range(iters):
        nxt = []
        for i, lab in enumerate(cur):
            ch = sorted(cur[c] for c in children[i])
            nxt.append(f"{lab}({','.join(ch)})")
        feats.update(nxt)
        cur = nxt
    return feats


def wl_similarity(fa: Counter, fb: Counter) -> float:
    """Cosine similarity of normalized label-frequency vectors."""
    keys = set(fa) | set(fb)
    if not keys:
        return 1.0
    va = np.array([fa.get(k, 0) for k in keys], dtype=np.float64)
    vb = np.array([fb.get(k, 0) for k in keys], dtype=np.float64)
    na, nb = np.linalg.norm(va), np.linalg.norm(vb)
    if na == 0 or nb == 0:
        return 0.0
    return float(va @ vb / (na * nb))


# ---------------------------------------------------------------------------
# Model2Vec initial labels (Alg. 7): kind + FLOPs bucket
# ---------------------------------------------------------------------------

def graph_wl(g: MLGraph, in_dims: List[int] | None = None,
             flops_bucket: float = 4.0, iters: int = 3) -> Counter:
    in_dims = in_dims or [64] * g.n_inputs
    dims = g.infer_dims(in_dims)
    labels, children = [], []
    idx = {n.id: i for i, n in enumerate(g.nodes)}
    for n in g.nodes:
        arg_dims = [in_dims[r[1]] if r[0] == "in" else dims[r[1]] for r in n.args]
        fl = max(n.atom.flops_per_row(arg_dims), 1.0)
        bucket = int(math.log(fl, flops_bucket))
        labels.append(f"{n.atom.kind}:{bucket}")
        children.append([idx[r[1]] for r in n.args if r[0] == "node"])
    return wl_features(labels, children, iters)


# ---------------------------------------------------------------------------
# Query2Vec initial labels (Alg. 9): per relational node type
# ---------------------------------------------------------------------------

def _pred_label(e: ir.Expr) -> str:
    if isinstance(e, ir.Cmp):
        col = e.a.name if isinstance(e.a, ir.Col) else "?"
        val = f"{e.b.value:.2g}" if isinstance(e.b, ir.Const) else "?"
        return f"{col}{e.op}{val}"
    if isinstance(e, ir.BoolOp):
        return f"{e.op}[{'|'.join(_pred_label(a) for a in e.args)}]"
    if isinstance(e, ir.IsIn):
        return f"in:{e.a.name if isinstance(e.a, ir.Col) else '?'}:{len(e.values)}"
    if isinstance(e, ir.Call):
        return f"ml:{_canon_fn(e.fn)}"
    return type(e).__name__


def _canon_fn(name: str) -> str:
    """Strip rule-generated suffixes so rewritten plans of the same model
    share labels."""
    for tag in ("_fact", "_dfact", "_fused", "_unfused", "_be", "_sub",
                "_res", "_mm", "_pre", "_post", "_rel"):
        i = name.find(tag)
        if i > 0:
            return name[:i]
    return name


def plan_wl(node: ir.RelNode, registry, iters: int = 3, phys=None) -> Counter:
    """WL features of a plan; ``phys`` (``Plan.phys``) labels physical
    realization choices of BlockedMatmul/ForestRelational nodes."""
    phys = phys or {}
    labels: List[str] = []
    children: List[List[int]] = []

    def visit(n: ir.RelNode) -> int:
        kid_idx = [visit(c) for c in n.children()]
        if isinstance(n, ir.Scan):
            lab = f"scan:{n.table}"
        elif isinstance(n, ir.Filter):
            lab = f"filter:{_pred_label(n.pred)}"
        elif isinstance(n, ir.Compact):
            lab = "compact"
        elif isinstance(n, ir.Project):
            mls = ",".join(sorted(_pred_label(e) for _, e in n.outputs))
            lab = f"project:{mls}"
        elif isinstance(n, ir.Join):
            lab = f"join:{n.left_key}={n.right_key}"
        elif isinstance(n, ir.CrossJoin):
            lab = "crossjoin"
        elif isinstance(n, ir.Aggregate):
            lab = f"agg:{n.key}:{','.join(k for _, (k, _) in n.aggs)}"
        elif isinstance(n, ir.BlockedMatmul):
            mode = phys.get(n.uid, ir.DEFAULT_PHYS).mode
            lab = f"blockedmm:{_canon_fn(n.fn)}:{mode}"
        elif isinstance(n, ir.ForestRelational):
            mode = phys.get(n.uid, ir.DEFAULT_PHYS).mode
            lab = f"forestrel:{_canon_fn(n.fn)}:{mode}"
        else:
            lab = type(n).__name__
        labels.append(lab)
        children.append(kid_idx)
        return len(labels) - 1

    visit(node)
    return wl_features(labels, children, iters)
