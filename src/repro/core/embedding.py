"""Model2Vec + Query2Vec (paper Sec. IV-B) in pure JAX.

Model2Vec embeds a bottom-level IR (BFS node sequence; features E_mlType,
E_mlFlops, E_mlDims) with a small transformer into a 64-d expression vector
E_expr.

Query2Vec builds one 393-d vector per top-level IR node per Eq. 1:
  E_o(64) ‖ E_j(64) ‖ E_t(64) ‖ E_p(64+8+1) ‖ E_h(64) ‖ E_s(64)  = 393
where the predicate's 64-d filter embedding carries either a column
embedding (native SQL filters, selectivity via E_h/E_s) or the Model2Vec
E_expr (AI/ML filters — selectivity learned implicitly, Sec. IV-B1), then
runs a tree transformer with height encodings and mean-pools to the final
393-d state embedding.

Training: Task-1 contrastive loss (Eq. 2-3) over WL-kernel-mined pairs;
Task-2 latency head (4-layer FFNN, MSE on log latency). The two-model
strategy trains them on separate copies (joint training = one-model
baseline, kept for the Sec. V-E comparison).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ir
from repro.mlfuncs.functions import MLGraph

# -- dimensions (paper Sec. IV-B2) ------------------------------------------
EXPR_DIM = 64
NODE_DIM = 393           # 5*64 + (64+8+1)
D_MODEL = 384            # transformer width (6 heads x 64)
MAX_GRAPH_NODES = 64
MAX_PLAN_NODES = 32
GRAPH_FEAT = 24 + 2 + 4  # type one-hot + [log flops, log dim] + dim histogram
N_KINDS = 24
_KINDS = ["matmul", "bias", "act", "concat", "cossim", "dot", "dist", "embed",
          "scale", "onehot", "forest", "fused_dense", "binarize", "slice",
          "add", "mul", "sqrt", "argmin", "const_vec", "opaque"]
_OPS = [">", "<", ">=", "<=", "==", "!=", "and", "or", "not", "isin"]


def _hash(s: str, mod: int) -> int:
    h = 2166136261
    for ch in s:
        h = ((h ^ ord(ch)) * 16777619) & 0xFFFFFFFF
    return h % mod


# ===========================================================================
# tiny transformer
# ===========================================================================

def _init_linear(rng, din, dout):
    k1, _ = jax.random.split(rng)
    return {"w": jax.random.normal(k1, (din, dout)) / np.sqrt(din),
            "b": jnp.zeros((dout,))}


def _linear(p, x):
    return x @ p["w"] + p["b"]


def _init_block(rng, d, heads):
    ks = jax.random.split(rng, 6)
    return {
        "qkv": _init_linear(ks[0], d, 3 * d),
        "o": _init_linear(ks[1], d, d),
        "m1": _init_linear(ks[2], d, 4 * d),
        "m2": _init_linear(ks[3], 4 * d, d),
        "ln1": {"g": jnp.ones((d,)), "b": jnp.zeros((d,))},
        "ln2": {"g": jnp.ones((d,)), "b": jnp.zeros((d,))},
    }


def _ln(p, x):
    mu = x.mean(-1, keepdims=True)
    sd = jnp.sqrt(((x - mu) ** 2).mean(-1, keepdims=True) + 1e-6)
    return (x - mu) / sd * p["g"] + p["b"]


def _block(p, x, mask, heads):
    # x: [n, d]; mask: [n] bool
    n, d = x.shape
    h = _ln(p["ln1"], x)
    qkv = _linear(p["qkv"], h).reshape(n, 3, heads, d // heads)
    q, k, v = qkv[:, 0], qkv[:, 1], qkv[:, 2]
    s = jnp.einsum("nhd,mhd->hnm", q, k) / np.sqrt(d // heads)
    s = jnp.where(mask[None, None, :], s, -1e30)
    a = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("hnm,mhd->nhd", a, v).reshape(n, d)
    x = x + _linear(p["o"], o)
    h = _ln(p["ln2"], x)
    x = x + _linear(p["m2"], jax.nn.gelu(_linear(p["m1"], h)))
    return x


# ===========================================================================
# Model2Vec
# ===========================================================================

def init_model2vec(rng) -> Dict:
    ks = jax.random.split(rng, 5)
    return {
        "in": _init_linear(ks[0], GRAPH_FEAT, EXPR_DIM),
        "blocks": [_init_block(ks[1], EXPR_DIM, 4),
                   _init_block(ks[2], EXPR_DIM, 4)],
        "out": _init_linear(ks[3], EXPR_DIM, EXPR_DIM),
    }


@functools.partial(jax.jit, static_argnames=())
def model2vec_apply(params, feats, mask):
    x = _linear(params["in"], feats)
    for blk in params["blocks"]:
        x = _block(blk, x, mask, 4)
    m = mask[:, None].astype(x.dtype)
    pooled = (x * m).sum(0) / jnp.maximum(m.sum(), 1.0)
    out = _linear(params["out"], pooled)
    return out / (jnp.linalg.norm(out) + 1e-8)


def featurize_graph(g: Optional[MLGraph], in_dims: Optional[List[int]] = None
                    ) -> Tuple[np.ndarray, np.ndarray]:
    """BFS node features: E_mlType (one-hot), E_mlFlops, E_mlDims."""
    feats = np.zeros((MAX_GRAPH_NODES, GRAPH_FEAT), np.float32)
    mask = np.zeros((MAX_GRAPH_NODES,), bool)
    if g is None:
        feats[0, N_KINDS - 1] = 1.0  # opaque marker
        mask[0] = True
        return feats, mask
    in_dims = in_dims or [64] * g.n_inputs
    dims = g.infer_dims(in_dims)
    # BFS from output (paper: breadth-first traversal)
    order, frontier, seen = [], [g.out], set()
    by_id = {n.id: n for n in g.nodes}
    while frontier:
        nxt = []
        for nid in frontier:
            if nid in seen:
                continue
            seen.add(nid)
            order.append(nid)
            for r in by_id[nid].args:
                if r[0] == "node":
                    nxt.append(r[1])
        frontier = nxt
    for i, nid in enumerate(order[:MAX_GRAPH_NODES]):
        n = by_id[nid]
        arg_dims = [in_dims[r[1]] if r[0] == "in" else dims[r[1]] for r in n.args]
        kidx = _KINDS.index(n.atom.kind) if n.atom.kind in _KINDS else N_KINDS - 1
        feats[i, kidx] = 1.0
        fl = max(n.atom.flops_per_row(arg_dims), 1.0)
        feats[i, N_KINDS] = np.log1p(fl) / 10.0
        feats[i, N_KINDS + 1] = np.log1p(max(dims[nid], 1)) / 10.0
        d = max(dims[nid], 1)
        feats[i, N_KINDS + 2 + min(3, int(np.log2(d) // 3))] = 1.0
        mask[i] = True
    return feats, mask


# ===========================================================================
# Query2Vec
# ===========================================================================

def init_query2vec(rng) -> Dict:
    ks = jax.random.split(rng, 12)
    return {
        "op_embed": jax.random.normal(ks[0], (12, 64)) * 0.1,       # E_o
        "join_embed": jax.random.normal(ks[1], (4, 64)) * 0.1,      # E_j
        "table_embed": jax.random.normal(ks[2], (64, 64)) * 0.1,    # E_t
        "col_embed": jax.random.normal(ks[3], (64, 64)) * 0.1,      # E_p filter
        "expr_proj": _init_linear(ks[4], EXPR_DIM, 64),             # E_expr -> filter slot
        "pred_op": jax.random.normal(ks[5], (11, 8)) * 0.1,         # E_p op
        "hist": _init_linear(ks[6], 8, 64),                         # E_h
        "sample": _init_linear(ks[7], 64, 64),                      # E_s
        "in": _init_linear(ks[8], NODE_DIM, D_MODEL),
        "height": jax.random.normal(ks[9], (16, D_MODEL)) * 0.02,
        "blocks": [_init_block(ks[10], D_MODEL, 6),
                   _init_block(ks[11], D_MODEL, 6)],
        "out": _init_linear(jax.random.split(ks[0])[0], D_MODEL, NODE_DIM),
    }


_REL_OPS = ["scan", "filter", "project", "join", "crossjoin", "aggregate",
            "compact", "blockedmm", "forestrel", "union", "other"]


@dataclasses.dataclass
class PlanFeatures:
    """Host-side featurization of one plan (numpy)."""
    op_ids: np.ndarray       # [P] int
    join_ids: np.ndarray     # [P] int
    table_ids: np.ndarray    # [P] int
    col_ids: np.ndarray      # [P] int
    has_expr: np.ndarray     # [P] float (1 -> use E_expr in the filter slot)
    expr_feats: np.ndarray   # [P, MAX_GRAPH_NODES, GRAPH_FEAT]
    expr_masks: np.ndarray   # [P, MAX_GRAPH_NODES]
    pred_ops: np.ndarray     # [P] int
    pred_vals: np.ndarray    # [P] float
    hists: np.ndarray        # [P, 8]
    samples: np.ndarray      # [P, 64]
    heights: np.ndarray      # [P] int
    mask: np.ndarray         # [P] bool


def featurize_plan(plan: ir.Plan, catalog: ir.Catalog) -> PlanFeatures:
    P = MAX_PLAN_NODES
    f = PlanFeatures(
        op_ids=np.zeros(P, np.int32), join_ids=np.zeros(P, np.int32),
        table_ids=np.zeros(P, np.int32), col_ids=np.zeros(P, np.int32),
        has_expr=np.zeros(P, np.float32),
        expr_feats=np.zeros((P, MAX_GRAPH_NODES, GRAPH_FEAT), np.float32),
        expr_masks=np.zeros((P, MAX_GRAPH_NODES), bool),
        pred_ops=np.zeros(P, np.int32), pred_vals=np.zeros(P, np.float32),
        hists=np.zeros((P, 8), np.float32), samples=np.zeros((P, 64), np.float32),
        heights=np.zeros(P, np.int32), mask=np.zeros(P, bool))
    i = [0]

    def first_call(e: ir.Expr):
        if isinstance(e, ir.Call):
            return e
        for c in e.children():
            r = first_call(c)
            if r is not None:
                return r
        return None

    def visit(n: ir.RelNode, height: int):
        # in-order: left subtree, node, right subtree (paper Sec. IV-B1)
        kids = n.children()
        if kids:
            visit(kids[0], height + 1)
        k = i[0]
        if k < P:
            if isinstance(n, ir.Scan):
                op = "scan"
                f.table_ids[k] = _hash(n.table, 64)
                st = catalog.stats.get(n.table)
                if st is not None and st.sample_bitmap is not None:
                    f.samples[k] = st.sample_bitmap
            elif isinstance(n, ir.Filter):
                op = "filter"
                _pred_features(f, k, n.pred, plan.registry, catalog)
            elif isinstance(n, ir.Project):
                op = "project"
                calls = [c for _, e in n.outputs for c in [first_call(e)] if c]
                if calls:
                    _call_features(f, k, calls[0], plan.registry)
            elif isinstance(n, ir.Join):
                op = "join"
                f.join_ids[k] = 1
                f.col_ids[k] = _hash(n.left_key, 64)
            elif isinstance(n, ir.CrossJoin):
                op = "crossjoin"
                f.join_ids[k] = 2
            elif isinstance(n, ir.Aggregate):
                op = "aggregate"
                f.col_ids[k] = _hash(n.key, 64)
            elif isinstance(n, ir.Compact):
                op = "compact"
                f.pred_vals[k] = np.log1p(n.capacity) / 20.0
            elif isinstance(n, ir.BlockedMatmul):
                op = "blockedmm"
                fn = plan.registry.get(n.fn)
                ef, em = featurize_graph(fn.graph)
                f.expr_feats[k], f.expr_masks[k] = ef, em
                f.has_expr[k] = 1.0
                pc = plan.phys_for(n)
                f.pred_vals[k] = pc.n_tiles / 16.0 + (0.5 if pc.backend == "pallas" else 0.0)
            elif isinstance(n, ir.ForestRelational):
                op = "forestrel"
                fn = plan.registry.get(n.fn)
                ef, em = featurize_graph(fn.graph)
                f.expr_feats[k], f.expr_masks[k] = ef, em
                f.has_expr[k] = 1.0
            else:
                op = "other"
            f.op_ids[k] = _REL_OPS.index(op)
            f.heights[k] = min(height, 15)
            f.mask[k] = True
        i[0] += 1
        for c in kids[1:]:
            visit(c, height + 1)

    def _pred_features(f, k, pred, registry, catalog):
        if isinstance(pred, ir.BoolOp) and pred.args:
            pred_inner = pred.args[0]
        else:
            pred_inner = pred
        if isinstance(pred_inner, ir.Cmp):
            f.pred_ops[k] = _OPS.index(pred_inner.op)
            if isinstance(pred_inner.b, ir.Const):
                f.pred_vals[k] = np.tanh(pred_inner.b.value / 100.0)
            c = first_call(pred_inner)
            if c is not None:
                _call_features(f, k, c, registry)
            elif isinstance(pred_inner.a, ir.Col):
                f.col_ids[k] = _hash(pred_inner.a.name, 64)
                for st in catalog.stats.values():
                    cs = st.columns.get(pred_inner.a.name)
                    if cs is not None and cs.histogram is not None:
                        f.hists[k] = cs.histogram
                        break
        elif isinstance(pred_inner, ir.IsIn):
            f.pred_ops[k] = _OPS.index("isin")
            f.pred_vals[k] = len(pred_inner.values) / 16.0
            if isinstance(pred_inner.a, ir.Col):
                f.col_ids[k] = _hash(pred_inner.a.name, 64)

    def _call_features(f, k, call: ir.Call, registry):
        fn = registry.get(call.fn)
        ef, em = featurize_graph(fn.graph)
        f.expr_feats[k], f.expr_masks[k] = ef, em
        f.has_expr[k] = 1.0

    visit(plan.root, 0)
    return f


@jax.jit
def query2vec_apply(params: Dict, m2v_params: Dict, pf_arrays) -> jax.Array:
    (op_ids, join_ids, table_ids, col_ids, has_expr, expr_feats, expr_masks,
     pred_ops, pred_vals, hists, samples, heights, mask) = pf_arrays
    e_o = params["op_embed"][op_ids]                        # [P, 64]
    e_j = params["join_embed"][join_ids]
    e_t = params["table_embed"][table_ids]
    e_expr = jax.vmap(lambda ft, mk: model2vec_apply(m2v_params, ft, mk))(
        expr_feats, expr_masks)                             # [P, 64]
    col_vec = params["col_embed"][col_ids]
    filt = jnp.where(has_expr[:, None] > 0,
                     _linear(params["expr_proj"], e_expr), col_vec)
    e_p = jnp.concatenate([filt, params["pred_op"][pred_ops],
                           pred_vals[:, None]], axis=1)     # [P, 73]
    e_h = _linear(params["hist"], hists)
    e_s = _linear(params["sample"], samples)
    node = jnp.concatenate([e_o, e_j, e_t, e_p, e_h, e_s], axis=1)  # [P, 393]
    x = _linear(params["in"], node) + params["height"][heights]
    for blk in params["blocks"]:
        x = _block(blk, x, mask, 6)
    m = mask[:, None].astype(x.dtype)
    pooled = (x * m).sum(0) / jnp.maximum(m.sum(), 1.0)
    out = _linear(params["out"], pooled)
    return out / (jnp.linalg.norm(out) + 1e-8)


def pf_to_arrays(pf: PlanFeatures):
    return (pf.op_ids, pf.join_ids, pf.table_ids, pf.col_ids, pf.has_expr,
            pf.expr_feats, pf.expr_masks, pf.pred_ops, pf.pred_vals, pf.hists,
            pf.samples, pf.heights, pf.mask)


# ===========================================================================
# latency head (Task 2: 4-layer FFNN on the query embedding)
# ===========================================================================

def init_latency_head(rng) -> Dict:
    ks = jax.random.split(rng, 4)
    return {"l1": _init_linear(ks[0], NODE_DIM, 256),
            "l2": _init_linear(ks[1], 256, 128),
            "l3": _init_linear(ks[2], 128, 64),
            "l4": _init_linear(ks[3], 64, 1)}


def latency_apply(params: Dict, emb: jax.Array) -> jax.Array:
    h = jax.nn.relu(_linear(params["l1"], emb))
    h = jax.nn.relu(_linear(params["l2"], h))
    h = jax.nn.relu(_linear(params["l3"], h))
    return _linear(params["l4"], h)[..., 0]


# ===========================================================================
# losses (Eq. 2-4)
# ===========================================================================

def contrastive_loss(anchor, pos, neg, tau: float = 0.2):
    """Eq. 3: -log exp(sim+ / tau) / (exp(sim- / tau) + exp(sim+ / tau))."""
    sp = jnp.sum(anchor * pos, -1) / tau
    sn = jnp.sum(anchor * neg, -1) / tau
    return jnp.mean(-(sp - jnp.logaddexp(sp, sn)))


def latency_loss(pred_log, true_log):
    return jnp.mean((pred_log - true_log) ** 2)
