"""Lowering pass: logical Plan -> PhysicalPlan.

By default lowering is *cost-driven* (``costed=True``): the plan becomes a
stage-DAG of candidate decisions (``core.stage_graph``) and
``core.costed_lowering`` picks the min-cost physical realization through
the shared ``cost.plan_cost`` oracle. The tree-order heuristic below
(``costed=False``) remains the baseline: realization choices come from the
plan's physical side table (``plan.phys``, keyed by node uid); nodes
without an annotation get ``ir.DEFAULT_PHYS`` with the tile count sized
from the weight (same policy R3-1 uses when it annotates). Adjacent
row-local operators (Filter, Project, Compact) fuse into a single
``PPipeline`` stage chain — one driver per pipeline instead of one
interpreter dispatch per logical node.

``backend`` overrides every annotation's backend ('jnp' forces the pure-XLA
path, 'pallas' the TPU kernels) without touching the plan — the paper's
"re-realize without touching the logical query" knob. ``backend="sharded"``
is the multi-device realization: per-node it resolves to the pure-XLA path
(each mesh device runs an ordinary single-device program on its slice of the
stacked batch axis — see ``PlanCache.get_or_compile_sharded``), while the
choice itself stays first-class in compiled-plan cache keys.
"""
from __future__ import annotations

from typing import Optional, Tuple

from repro.core import ir
from repro.core import physical as ph


# plan-level realizations and the node-level backend they resolve to: the
# sharded path splits the stacked batch axis *around* the plan body, so each
# device's slice runs the ordinary pure-XLA program
_PLAN_LEVEL_BACKENDS = {"sharded": "jnp"}


def _config(plan: ir.Plan, node: ir.RelNode,
            backend: Optional[str]) -> ir.PhysConfig:
    cfg = plan.phys_for(node)  # resolves the weight-derived n_tiles default
    if backend is not None:
        backend = _PLAN_LEVEL_BACKENDS.get(backend, backend)
        cfg = ir.PhysConfig(mode=cfg.mode, backend=backend, n_tiles=cfg.n_tiles)
    return cfg


_ROW_LOCAL = (ir.Filter, ir.Project, ir.Compact)


def _as_stage(node: ir.RelNode) -> ph.Stage:
    if isinstance(node, ir.Filter):
        return ph.FilterStage(pred=node.pred)
    if isinstance(node, ir.Project):
        return ph.ProjectStage(outputs=node.outputs, keep=node.keep)
    if isinstance(node, ir.Compact):
        return ph.CompactStage(capacity=node.capacity)
    raise TypeError(type(node))


def _lower_node(node: ir.RelNode, plan: ir.Plan, catalog: ir.Catalog,
                backend: Optional[str]) -> ph.PhysNode:
    if isinstance(node, _ROW_LOCAL):
        # collect the maximal Filter/Project/Compact chain (Velox-style
        # pipeline); stages execute source-to-sink, so reverse the walk
        stages: list = []
        cur = node
        while isinstance(cur, _ROW_LOCAL):
            stages.append(_as_stage(cur))
            cur = cur.children()[0]
        return ph.PPipeline(child=_lower_node(cur, plan, catalog, backend),
                            stages=tuple(reversed(stages)))
    if isinstance(node, ir.Scan):
        return ph.PScan(table=node.table)
    if isinstance(node, ir.Join):
        return ph.PJoin(left=_lower_node(node.left, plan, catalog, backend),
                        right=_lower_node(node.right, plan, catalog, backend),
                        left_key=node.left_key, right_key=node.right_key,
                        rprefix=node.rprefix)
    if isinstance(node, ir.CrossJoin):
        return ph.PCrossJoin(left=_lower_node(node.left, plan, catalog, backend),
                             right=_lower_node(node.right, plan, catalog, backend),
                             aprefix=node.aprefix, bprefix=node.bprefix)
    if isinstance(node, ir.Aggregate):
        return ph.PAggregate(child=_lower_node(node.child, plan, catalog, backend),
                             key=node.key, aggs=node.aggs,
                             num_groups=node.num_groups)
    if isinstance(node, ir.BlockedMatmul):
        cfg = _config(plan, node, backend)
        return ph.PBlockedMatmul(
            child=_lower_node(node.child, plan, catalog, backend),
            x_col=node.x_col, out_col=node.out_col, fn=node.fn,
            n_tiles=cfg.n_tiles, mode=cfg.mode, backend=cfg.backend,
            keep=node.keep)
    if isinstance(node, ir.ForestRelational):
        cfg = _config(plan, node, backend)
        return ph.PForestRelational(
            child=_lower_node(node.child, plan, catalog, backend),
            x_col=node.x_col, out_col=node.out_col, fn=node.fn,
            mode=cfg.mode, backend=cfg.backend, keep=node.keep)
    raise TypeError(type(node))


def lower(plan: ir.Plan, catalog: ir.Catalog, *,
          backend: Optional[str] = None, costed: bool = True,
          profile=None, memory_budget: Optional[float] = None,
          ways: int = 1) -> ph.PhysicalPlan:
    """Lower a logical plan to its physical realization.

    By default lowering is *cost-driven*: the plan is turned into a
    stage-DAG of candidate decisions (``core.stage_graph``) and the min-cost
    realization under the shared analytic oracle is picked
    (``core.costed_lowering`` / ``cost.plan_cost``) — ``catalog`` supplies
    the statistics those decisions need. ``costed=False`` keeps the
    tree-order heuristic (one stage per logical node, pipelines fused in
    tree order) — also the costed path's baseline and the shape
    ``plan_cost`` assumes when costing a *logical* plan. ``backend``
    force-overrides every node's backend annotation in either mode;
    ``profile``/``memory_budget`` parameterize the costed oracle.
    ``ways > 1`` (costed only) opens per-node ``PartSpec`` candidates —
    intra-query sharding over a ``ways``-device data mesh, with explicit
    ``PRepartition`` boundaries; the resulting plan must execute inside
    ``shard_map`` (``PlanCache.get_or_compile_partitioned``).
    """
    if costed:
        from repro.core.costed_lowering import lower_costed
        return lower_costed(plan, catalog, backend=backend, profile=profile,
                            memory_budget=memory_budget, ways=ways).plan
    root = _lower_node(plan.root, plan, catalog, backend)
    return ph.PhysicalPlan(root=root, registry=plan.registry)
