"""CACTUSDB core: three-level IR, co-optimization rules O1-O4, analytic cost
model, plan executor, query embeddings, and the reusable MCTS optimizer."""
from repro.core.ir import (
    Expr, Col, Const, BinOp, Cmp, BoolOp, IsIn, IfExpr, Call,
    RelNode, Scan, Filter, Project, Join, CrossJoin, Aggregate, Compact,
    BlockedMatmul, ForestRelational, Plan, Catalog,
)

__all__ = [
    "Expr", "Col", "Const", "BinOp", "Cmp", "BoolOp", "IsIn", "IfExpr", "Call",
    "RelNode", "Scan", "Filter", "Project", "Join", "CrossJoin", "Aggregate",
    "Compact", "BlockedMatmul", "ForestRelational", "Plan", "Catalog",
]
