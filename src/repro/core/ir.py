"""Logical Intermediate Representation (paper Sec. III).

Top level    : relational operators (``RelNode`` subclasses) — each Filter /
               Project is customized by expressions that are opaque *at this
               level*.
Middle level : expression trees (``Expr`` subclasses) — arithmetic, compare,
               boolean, conditional, and CALLFUNC nodes.
Bottom level : ``Call`` resolves through the ML-function ``Registry`` to an
               ``MLGraph`` of atomic ML functions (repro.mlfuncs).

The *physical* level (repro.core.physical) is produced from this IR by
repro.core.lowering; logical nodes carry only semantics. Physical choices
(realization mode, kernel backend, tile counts) live in a side table on the
``Plan`` (``Plan.phys``), keyed by the stable ``uid`` of the annotated node,
so optimizer rules can re-realize a sub-computation without rebuilding the
logical tree.

A ``Plan`` bundles (root RelNode, Registry, physical side table); a
``Catalog`` holds base tables and their statistics (row counts, per-column
min/max/histograms — the E_h / E_s features of Query2Vec).

All IR nodes are immutable; rewrites build new trees with structural sharing.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.mlfuncs.registry import Registry


# ===========================================================================
# Middle-level IR: expressions
# ===========================================================================

class Expr:
    def cols(self) -> frozenset:
        raise NotImplementedError

    def children(self) -> Tuple["Expr", ...]:
        return ()


@dataclasses.dataclass(frozen=True)
class Col(Expr):
    name: str

    def cols(self):
        return frozenset([self.name])


@dataclasses.dataclass(frozen=True)
class Const(Expr):
    value: float

    def cols(self):
        return frozenset()


@dataclasses.dataclass(frozen=True)
class BinOp(Expr):
    op: str  # + - * /
    a: Expr
    b: Expr

    def cols(self):
        return self.a.cols() | self.b.cols()

    def children(self):
        return (self.a, self.b)


@dataclasses.dataclass(frozen=True)
class Cmp(Expr):
    op: str  # > < >= <= == !=
    a: Expr
    b: Expr

    def cols(self):
        return self.a.cols() | self.b.cols()

    def children(self):
        return (self.a, self.b)


@dataclasses.dataclass(frozen=True)
class BoolOp(Expr):
    op: str  # and or not
    args: Tuple[Expr, ...]

    def cols(self):
        s = frozenset()
        for a in self.args:
            s |= a.cols()
        return s

    def children(self):
        return self.args


@dataclasses.dataclass(frozen=True)
class IsIn(Expr):
    """Set membership on an integer-coded categorical column — our stand-in
    for the paper's LIKE '%Action%' genre predicates."""
    a: Expr
    values: Tuple[int, ...]

    def cols(self):
        return self.a.cols()

    def children(self):
        return (self.a,)


@dataclasses.dataclass(frozen=True)
class IfExpr(Expr):
    cond: Expr
    t: Expr
    f: Expr

    def cols(self):
        return self.cond.cols() | self.t.cols() | self.f.cols()

    def children(self):
        return (self.cond, self.t, self.f)


@dataclasses.dataclass(frozen=True)
class Call(Expr):
    """CALLFUNC — invoke a registered ML function on column expressions."""
    fn: str
    args: Tuple[Expr, ...]

    def cols(self):
        s = frozenset()
        for a in self.args:
            s |= a.cols()
        return s

    def children(self):
        return self.args


# ===========================================================================
# Top-level IR: relational operators
# ===========================================================================

class RelNode:
    def children(self) -> Tuple["RelNode", ...]:
        raise NotImplementedError

    def with_children(self, children: Sequence["RelNode"]) -> "RelNode":
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class Scan(RelNode):
    table: str

    def children(self):
        return ()

    def with_children(self, children):
        assert not children
        return self


@dataclasses.dataclass(frozen=True)
class Filter(RelNode):
    child: RelNode
    pred: Expr
    selectivity: Optional[float] = None  # user/optimizer hint

    def children(self):
        return (self.child,)

    def with_children(self, children):
        return dataclasses.replace(self, child=children[0])


@dataclasses.dataclass(frozen=True)
class Project(RelNode):
    """Adds computed columns. ``keep=None`` keeps all input columns;
    otherwise only ``keep`` plus the new outputs survive."""
    child: RelNode
    outputs: Tuple[Tuple[str, Expr], ...]
    keep: Optional[Tuple[str, ...]] = None

    def children(self):
        return (self.child,)

    def with_children(self, children):
        return dataclasses.replace(self, child=children[0])

    def outputs_dict(self) -> Dict[str, Expr]:
        return dict(self.outputs)


@dataclasses.dataclass(frozen=True)
class Join(RelNode):
    """FK inner equi-join (right side unique on key)."""
    left: RelNode
    right: RelNode
    left_key: str
    right_key: str
    rprefix: str = ""

    def children(self):
        return (self.left, self.right)

    def with_children(self, children):
        return dataclasses.replace(self, left=children[0], right=children[1])


@dataclasses.dataclass(frozen=True)
class CrossJoin(RelNode):
    left: RelNode
    right: RelNode
    aprefix: str = ""
    bprefix: str = ""

    def children(self):
        return (self.left, self.right)

    def with_children(self, children):
        return dataclasses.replace(self, left=children[0], right=children[1])


@dataclasses.dataclass(frozen=True)
class Aggregate(RelNode):
    child: RelNode
    key: str
    aggs: Tuple[Tuple[str, Tuple[str, str]], ...]  # out -> (kind, in_col)
    num_groups: int

    def children(self):
        return (self.child,)

    def with_children(self, children):
        return dataclasses.replace(self, child=children[0])


@dataclasses.dataclass(frozen=True)
class Compact(RelNode):
    """Physical: gather live rows into a smaller static capacity. Inserted by
    the optimizer after selective filters (TPU adaptation of pushdown payoff,
    see DESIGN.md Sec. 2)."""
    child: RelNode
    capacity: int

    def children(self):
        return (self.child,)

    def with_children(self, children):
        return dataclasses.replace(self, child=children[0])


_uid_counter = itertools.count()


def fresh_uid() -> str:
    """Stable identity for side-table annotations; survives with_children /
    dataclasses.replace rewrites and is excluded from structural equality."""
    return f"n{next(_uid_counter)}"


@dataclasses.dataclass(frozen=True)
class BlockedMatmul(RelNode):
    """Logical node produced by R3-1 (tensor-relational matMul).

    Semantics only: out_col[i] = x_col[i] @ W, where W is the weight of the
    (matmul-only) registered function ``fn``. The physical realization
    (relational vs fused pipeline, jnp vs pallas backend, tile count) is an
    annotation in ``Plan.phys`` keyed by ``uid`` and is chosen at lowering.
    """
    child: RelNode
    x_col: str
    out_col: str
    fn: str
    keep: Optional[Tuple[str, ...]] = None
    uid: str = dataclasses.field(default_factory=fresh_uid, compare=False)

    def children(self):
        return (self.child,)

    def with_children(self, children):
        return dataclasses.replace(self, child=children[0])


@dataclasses.dataclass(frozen=True)
class ForestRelational(RelNode):
    """Logical node produced by R3-2 (forest → crossJoin+project+aggregate).

    Semantics only: out_col[i] = forest_vote(x_col[i]). Whether the forest is
    realized relationally (crossJoin with the tree relation DF(treeId, feat,
    thresh, leaf) + aggregate) or fused per row, and on which backend, is a
    ``Plan.phys`` annotation keyed by ``uid``.
    """
    child: RelNode
    x_col: str
    out_col: str
    fn: str
    keep: Optional[Tuple[str, ...]] = None
    uid: str = dataclasses.field(default_factory=fresh_uid, compare=False)

    def children(self):
        return (self.child,)

    def with_children(self, children):
        return dataclasses.replace(self, child=children[0])


# ===========================================================================
# Physical configuration side table (annotations on Plan, consumed by
# repro.core.lowering — see DESIGN notes in that module)
# ===========================================================================

@dataclasses.dataclass(frozen=True)
class PhysConfig:
    """Physical realization choice for one BlockedMatmul/ForestRelational.

    mode    : 'relational' — literal tile/tree relation + crossJoin pipeline
              (paper Fig. 2); 'fused' — pipelined evaluation without
              materializing the product (Velox-style).
    backend : 'jnp' | 'pallas' (TPU kernels).
    n_tiles : weight-tile count for BlockedMatmul streaming.
    """
    mode: str = "fused"
    backend: str = "jnp"
    n_tiles: int = 4

    def signature(self) -> str:
        return f"{self.mode}/{self.backend}/{self.n_tiles}"


DEFAULT_PHYS = PhysConfig()


def default_n_tiles(registry: Registry, fn_name: str) -> int:
    """Tile-count policy for a blocked matmul: ~1MB per weight tile, clamped
    to [2, 16]. The single source of truth — R3-1 annotations, lowering
    defaults, the cost model, and the featurizer all resolve through here."""
    try:
        fn = registry.get(fn_name)
        w = np.asarray(fn.graph.nodes[0].atom.params["w"])
        return int(max(2, min(16, np.ceil(w.nbytes / (1 << 20)))))
    except Exception:
        return DEFAULT_PHYS.n_tiles


def resolve_phys(node: RelNode, phys: Optional[Mapping[str, PhysConfig]],
                 registry: Registry) -> PhysConfig:
    """The PhysConfig a node will actually execute with: its side-table
    annotation, or the default with a weight-derived tile count."""
    uid = getattr(node, "uid", "")
    cfg = (phys or {}).get(uid, DEFAULT_PHYS)
    if isinstance(node, BlockedMatmul) and uid not in (phys or {}):
        cfg = dataclasses.replace(cfg,
                                  n_tiles=default_n_tiles(registry, node.fn))
    return cfg


# ===========================================================================
# Catalog + Plan
# ===========================================================================

@dataclasses.dataclass
class ColumnStats:
    dim: int                 # 0 = scalar, d = vector
    min: float = 0.0
    max: float = 1.0
    histogram: Optional[np.ndarray] = None  # 8-bin equi-width (scalar cols)


@dataclasses.dataclass
class TableStats:
    rows: int
    capacity: int
    columns: Dict[str, ColumnStats]
    sample_bitmap: Optional[np.ndarray] = None  # E_s feature (64 samples)


class Catalog:
    """Base tables (JAX Tables) + numpy copies for the oracle + stats."""

    def __init__(self) -> None:
        self.tables: Dict[str, "object"] = {}
        self.np_tables: Dict[str, Dict[str, np.ndarray]] = {}
        self.stats: Dict[str, TableStats] = {}

    def add(self, name: str, table) -> None:
        from repro.relational.table import Table  # local import to avoid cycle
        assert isinstance(table, Table)
        self.tables[name] = table
        npt = table.to_numpy()
        self.np_tables[name] = npt
        cols: Dict[str, ColumnStats] = {}
        for cname, arr in npt.items():
            if arr.ndim == 1:
                a = arr.astype(np.float64)
                hist = np.histogram(a, bins=8)[0].astype(np.float32) if len(a) else None
                if hist is not None and hist.sum() > 0:
                    hist = hist / hist.sum()
                cols[cname] = ColumnStats(dim=0,
                                          min=float(a.min()) if len(a) else 0.0,
                                          max=float(a.max()) if len(a) else 1.0,
                                          histogram=hist)
            else:
                cols[cname] = ColumnStats(dim=int(arr.shape[1]))
        rng = np.random.default_rng(0)
        n = len(next(iter(npt.values()))) if npt else 0
        bitmap = (rng.random(64) < min(1.0, n / max(n, 1))).astype(np.float32) if n else None
        self.stats[name] = TableStats(rows=n, capacity=table.capacity,
                                      columns=cols, sample_bitmap=bitmap)


@dataclasses.dataclass
class Plan:
    root: RelNode
    registry: Registry
    # physical side table: node uid -> PhysConfig (logical tree stays pure)
    phys: Mapping[str, PhysConfig] = dataclasses.field(default_factory=dict)

    def replace_root(self, root: RelNode) -> "Plan":
        return Plan(root=root, registry=self.registry, phys=self.phys)

    def with_phys(self, uid: str, cfg: PhysConfig) -> "Plan":
        return Plan(root=self.root, registry=self.registry,
                    phys={**self.phys, uid: cfg})

    def phys_for(self, node: RelNode) -> PhysConfig:
        return resolve_phys(node, self.phys, self.registry)

    def signature(self) -> str:
        """Structural + physical-config signature (plan cache / embed keys)."""
        return plan_signature(self.root, self.phys)


# ===========================================================================
# Schema / stats propagation (used by rules + cost model + embeddings)
# ===========================================================================

@dataclasses.dataclass
class NodeInfo:
    schema: Dict[str, int]     # column -> dim
    rows: float                # live-row estimate
    capacity: int              # static capacity


def expr_dim(e: Expr, schema: Mapping[str, int], registry: Registry) -> int:
    if isinstance(e, Col):
        return schema[e.name]
    if isinstance(e, Const):
        return 0
    if isinstance(e, (BinOp,)):
        return max(expr_dim(e.a, schema, registry), expr_dim(e.b, schema, registry))
    if isinstance(e, (Cmp, BoolOp, IsIn)):
        return 0
    if isinstance(e, IfExpr):
        return max(expr_dim(e.t, schema, registry), expr_dim(e.f, schema, registry))
    if isinstance(e, Call):
        fn = registry.get(e.fn)
        in_dims = [expr_dim(a, schema, registry) for a in e.args]
        d = fn.out_dim(in_dims)
        return 0 if d <= 1 else d  # dim-1 vectors are scalar columns
    raise TypeError(type(e))


def expr_flops(e: Expr, schema: Mapping[str, int], registry: Registry) -> float:
    """FLOPs per row to evaluate the expression."""
    if isinstance(e, (Col, Const)):
        return 0.0
    if isinstance(e, (BinOp, Cmp)):
        d = max(1, expr_dim(e, schema, registry))
        return expr_flops(e.a, schema, registry) + expr_flops(e.b, schema, registry) + d
    if isinstance(e, BoolOp):
        return sum(expr_flops(a, schema, registry) for a in e.args) + 1
    if isinstance(e, IsIn):
        return expr_flops(e.a, schema, registry) + len(e.values)
    if isinstance(e, IfExpr):
        return (expr_flops(e.cond, schema, registry) + expr_flops(e.t, schema, registry)
                + expr_flops(e.f, schema, registry) + 1)
    if isinstance(e, Call):
        fn = registry.get(e.fn)
        in_dims = [expr_dim(a, schema, registry) for a in e.args]
        return (sum(expr_flops(a, schema, registry) for a in e.args)
                + fn.flops_per_row(in_dims))
    raise TypeError(type(e))


def estimate_selectivity(pred: Expr, schema, registry, catalog: Optional[Catalog],
                         table_hint: Optional[str] = None) -> float:
    """Crude selectivity estimate; ML predicates fall back to fn hints."""
    if isinstance(pred, BoolOp):
        sels = [estimate_selectivity(a, schema, registry, catalog, table_hint)
                for a in pred.args]
        if pred.op == "and":
            out = 1.0
            for s in sels:
                out *= s
            return out
        if pred.op == "or":
            out = 0.0
            for s in sels:
                out = out + s - out * s
            return out
        return max(0.0, 1.0 - sels[0])
    if isinstance(pred, Cmp):
        # uniform-assumption range estimate when one side is Const over a Col
        col, const = None, None
        if isinstance(pred.a, Col) and isinstance(pred.b, Const):
            col, const, op = pred.a, pred.b.value, pred.op
        elif isinstance(pred.b, Col) and isinstance(pred.a, Const):
            flip = {">": "<", "<": ">", ">=": "<=", "<=": ">="}
            col, const, op = pred.b, pred.a.value, flip.get(pred.op, pred.op)
        if col is not None and catalog is not None and table_hint is not None:
            st = catalog.stats.get(table_hint)
            if st and col.name in st.columns and st.columns[col.name].dim == 0:
                cs = st.columns[col.name]
                span = max(cs.max - cs.min, 1e-9)
                frac = float(np.clip((const - cs.min) / span, 0.0, 1.0))
                if op in ("<", "<="):
                    return max(frac, 1e-3)
                if op in (">", ">="):
                    return max(1.0 - frac, 1e-3)
                if op == "==":
                    return 0.05
                return 0.95
        return 0.33 if pred.op in (">", "<", ">=", "<=") else 0.1
    if isinstance(pred, IsIn):
        return min(1.0, 0.1 * len(pred.values) + 0.05)
    if isinstance(pred, Call):
        fn = registry.get(pred.fn)
        return fn.selectivity_hint if fn.selectivity_hint is not None else 0.5
    return 0.5


def infer(node: RelNode, registry: Registry, catalog: Catalog) -> NodeInfo:
    """Bottom-up schema + cardinality inference."""
    if isinstance(node, Scan):
        st = catalog.stats[node.table]
        return NodeInfo(schema={c: s.dim for c, s in st.columns.items()},
                        rows=float(st.rows), capacity=st.capacity)
    if isinstance(node, Filter):
        ci = infer(node.child, registry, catalog)
        sel = node.selectivity
        if sel is None:
            hint = _base_table_hint(node.child)
            sel = estimate_selectivity(node.pred, ci.schema, registry, catalog, hint)
        return NodeInfo(schema=ci.schema, rows=ci.rows * sel, capacity=ci.capacity)
    if isinstance(node, Compact):
        ci = infer(node.child, registry, catalog)
        return NodeInfo(schema=ci.schema, rows=min(ci.rows, node.capacity),
                        capacity=node.capacity)
    if isinstance(node, Project):
        ci = infer(node.child, registry, catalog)
        schema = dict(ci.schema) if node.keep is None else {k: ci.schema[k] for k in node.keep}
        for name, e in node.outputs:
            schema[name] = expr_dim(e, ci.schema, registry)
        return NodeInfo(schema=schema, rows=ci.rows, capacity=ci.capacity)
    if isinstance(node, Join):
        li = infer(node.left, registry, catalog)
        ri = infer(node.right, registry, catalog)
        schema = dict(li.schema)
        for c, d in ri.schema.items():
            out = node.rprefix + c
            if out == node.left_key and c == node.right_key:
                continue
            schema[out] = d
        return NodeInfo(schema=schema, rows=li.rows, capacity=li.capacity)
    if isinstance(node, CrossJoin):
        li = infer(node.left, registry, catalog)
        ri = infer(node.right, registry, catalog)
        schema = {node.aprefix + c: d for c, d in li.schema.items()}
        schema.update({node.bprefix + c: d for c, d in ri.schema.items()})
        return NodeInfo(schema=schema, rows=li.rows * ri.rows,
                        capacity=li.capacity * ri.capacity)
    if isinstance(node, Aggregate):
        ci = infer(node.child, registry, catalog)
        schema = {node.key: 0}
        for out, (kind, in_col) in node.aggs:
            schema[out] = 0 if kind == "count" else ci.schema.get(in_col, 0)
        rows = min(ci.rows, node.num_groups)
        return NodeInfo(schema=schema, rows=rows, capacity=node.num_groups)
    if isinstance(node, BlockedMatmul):
        ci = infer(node.child, registry, catalog)
        fn = registry.get(node.fn)
        schema = dict(ci.schema) if node.keep is None else {k: ci.schema[k] for k in node.keep}
        schema[node.out_col] = fn.out_dim([ci.schema[node.x_col]])
        return NodeInfo(schema=schema, rows=ci.rows, capacity=ci.capacity)
    if isinstance(node, ForestRelational):
        ci = infer(node.child, registry, catalog)
        schema = dict(ci.schema) if node.keep is None else {k: ci.schema[k] for k in node.keep}
        schema[node.out_col] = 0
        return NodeInfo(schema=schema, rows=ci.rows, capacity=ci.capacity)
    raise TypeError(type(node))


def _base_table_hint(node: RelNode) -> Optional[str]:
    while True:
        if isinstance(node, Scan):
            return node.table
        kids = node.children()
        if len(kids) != 1:
            return None
        node = kids[0]


# -- tree utilities ----------------------------------------------------------

def walk(node: RelNode):
    yield node
    for c in node.children():
        yield from walk(c)


def replace_node(root: RelNode, old: RelNode, new: RelNode) -> RelNode:
    if root is old:
        return new
    kids = root.children()
    if not kids:
        return root
    new_kids = tuple(replace_node(c, old, new) for c in kids)
    if all(a is b for a, b in zip(kids, new_kids)):
        return root
    return root.with_children(new_kids)


def plan_signature(node: RelNode,
                   phys: Optional[Mapping[str, PhysConfig]] = None) -> str:
    """Structural string (used for dedup in search and as cache keys).

    With ``phys`` given, BlockedMatmul/ForestRelational signatures include
    their physical-config annotation so plans that differ only in realization
    (the R4-2 choices) key distinctly.
    """
    if isinstance(node, Scan):
        return f"S({node.table})"
    if isinstance(node, Filter):
        return f"F({_expr_sig(node.pred)},{plan_signature(node.child, phys)})"
    if isinstance(node, Compact):
        return f"C({node.capacity},{plan_signature(node.child, phys)})"
    if isinstance(node, Project):
        outs = ",".join(f"{n}={_expr_sig(e)}" for n, e in node.outputs)
        return f"P({outs};{node.keep};{plan_signature(node.child, phys)})"
    if isinstance(node, Join):
        return (f"J({node.left_key}={node.right_key},"
                f"{plan_signature(node.left, phys)},"
                f"{plan_signature(node.right, phys)})")
    if isinstance(node, CrossJoin):
        return (f"X({plan_signature(node.left, phys)},"
                f"{plan_signature(node.right, phys)})")
    if isinstance(node, Aggregate):
        aggs = ",".join(f"{o}={k}:{c}" for o, (k, c) in node.aggs)
        return f"A({node.key};{aggs};{plan_signature(node.child, phys)})"
    if isinstance(node, (BlockedMatmul, ForestRelational)):
        cfg = (phys or {}).get(node.uid, DEFAULT_PHYS)
        tag = "BM" if isinstance(node, BlockedMatmul) else "FR"
        return (f"{tag}({node.x_col}->{node.out_col},{node.fn},"
                f"{cfg.signature()},{plan_signature(node.child, phys)})")
    raise TypeError(type(node))


def _expr_sig(e: Expr) -> str:
    if isinstance(e, Col):
        return e.name
    if isinstance(e, Const):
        return f"{e.value:g}"
    if isinstance(e, BinOp):
        return f"({_expr_sig(e.a)}{e.op}{_expr_sig(e.b)})"
    if isinstance(e, Cmp):
        return f"({_expr_sig(e.a)}{e.op}{_expr_sig(e.b)})"
    if isinstance(e, BoolOp):
        return f"{e.op}({','.join(_expr_sig(a) for a in e.args)})"
    if isinstance(e, IsIn):
        return f"in({_expr_sig(e.a)},{self_values(e)})"
    if isinstance(e, IfExpr):
        return f"if({_expr_sig(e.cond)},{_expr_sig(e.t)},{_expr_sig(e.f)})"
    if isinstance(e, Call):
        return f"{e.fn}({','.join(_expr_sig(a) for a in e.args)})"
    raise TypeError(type(e))


def self_values(e: IsIn) -> str:
    return "|".join(str(v) for v in e.values)
