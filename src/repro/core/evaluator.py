"""Unified backend-parameterized expression evaluator (middle-level IR).

One ``eval_expr(e, t, registry, xp=jnp|np)`` replaces the old duplicated
pair (``executor.eval_expr`` over jnp Tables + ``np_eval.eval_np`` over numpy
dicts). ``t`` is anything supporting ``t[col] -> array`` — a relational
Table or a plain dict of numpy arrays; ``xp`` is the array namespace.

Constants evaluate to scalars and rely on broadcasting (never a full
``(capacity,)`` materialization); callers that need a column-shaped result
(e.g. Project outputs) broadcast explicitly via ``as_column``.
"""
from __future__ import annotations

import functools
from typing import Any

import jax.numpy as jnp
import numpy as np

from repro.core import ir
from repro.mlfuncs.registry import Registry


def eval_expr(e: ir.Expr, t: Any, registry: Registry, xp=jnp):
    if isinstance(e, ir.Col):
        return t[e.name]
    if isinstance(e, ir.Const):
        return xp.float32(e.value)  # scalar; broadcasting handles the rest
    if isinstance(e, ir.BinOp):
        a = eval_expr(e.a, t, registry, xp)
        b = eval_expr(e.b, t, registry, xp)
        a, b = _align(a, b)
        if e.op == "+":
            return a + b
        if e.op == "-":
            return a - b
        if e.op == "*":
            return a * b
        if e.op == "/":
            return a / xp.where(b == 0, xp.float32(1e-9), b)
        raise ValueError(e.op)
    if isinstance(e, ir.Cmp):
        a = eval_expr(e.a, t, registry, xp)
        b = eval_expr(e.b, t, registry, xp)
        a, b = _align(a, b)
        return {"<": a < b, ">": a > b, "<=": a <= b, ">=": a >= b,
                "==": a == b, "!=": a != b}[e.op]
    if isinstance(e, ir.BoolOp):
        vals = [xp.asarray(eval_expr(a, t, registry, xp)).astype(bool)
                for a in e.args]
        if e.op == "and":
            return functools.reduce(xp.logical_and, vals)
        if e.op == "or":
            return functools.reduce(xp.logical_or, vals)
        if e.op == "not":
            return xp.logical_not(vals[0])
        raise ValueError(e.op)
    if isinstance(e, ir.IsIn):
        a = xp.asarray(eval_expr(e.a, t, registry, xp)).astype(xp.int32)
        out = xp.zeros_like(a, dtype=bool)
        for v in e.values:
            out = out | (a == v)
        return out
    if isinstance(e, ir.IfExpr):
        c = xp.asarray(eval_expr(e.cond, t, registry, xp)).astype(bool)
        return xp.where(c, eval_expr(e.t, t, registry, xp),
                        eval_expr(e.f, t, registry, xp))
    if isinstance(e, ir.Call):
        fn = registry.get(e.fn)
        args = [jnp.asarray(eval_expr(a, t, registry, xp)) for a in e.args]
        out = fn.apply(*args)
        if out.ndim == 2 and out.shape[1] == 1:
            out = out[:, 0]  # dim-1 vectors are scalar columns
        return out if xp is jnp else np.asarray(out)
    raise TypeError(type(e))


def _align(a, b):
    """Insert the broadcast axis when mixing vector [N, d] and scalar [N]
    columns; true scalars (ndim 0) broadcast natively."""
    a_nd = getattr(a, "ndim", 0)
    b_nd = getattr(b, "ndim", 0)
    if a_nd == 2 and b_nd == 1:
        return a, b[:, None]
    if a_nd == 1 and b_nd == 2:
        return a[:, None], b
    return a, b


def as_column(val, capacity: int, xp=jnp):
    """Broadcast a scalar evaluation result to a [capacity] column (Table
    columns must have the row axis)."""
    if getattr(val, "ndim", 0) == 0:
        return xp.full((capacity,), val)
    return val


def has_call(e: ir.Expr) -> bool:
    if isinstance(e, ir.Call):
        return True
    return any(has_call(c) for c in e.children())
