"""Physical plan layer: operators the backend actually runs.

Produced from the logical IR by ``repro.core.lowering.lower``; executed by
``run`` below. Physical nodes are where realization choices live — the
logical tree never carries mode/backend/tile decisions (those are ``ir.Plan``
side-table annotations consumed at lowering time).

Operators:
  PScan            — catalog table lookup.
  PPipeline        — a fused chain of row-local stages (Filter / Project /
                     Compact), executed one table pass per stage without
                     per-node interpreter dispatch (Velox-style driver).
  PJoin/PCrossJoin — relational joins (repro.relational.ops).
  PAggregate       — group-by.
  PBlockedMatmul   — R3-1 realization: 'relational' streams the weight-tile
                     relation (paper Fig. 2); 'fused' is the pipelined blocked
                     matmul; backend 'pallas' uses the TPU kernel.
  PForestRelational— R3-2 realization: 'relational' streams the tree relation;
                     'fused' evaluates the ensemble per row.
  PRepartition     — intra-query partition boundary: converts its child's
                     row distribution (replicated / row-block / hash-bucket
                     over the mesh's data axis) into the one its consumer
                     executes under, via ``shard_map`` collectives.

Partitioning is an explicit per-node decision, not a whole-plan property:
``PhysicalPlan.parts`` is a side table (mirroring ``ir.Plan.phys``) mapping
each node's tree path to the ``PartSpec`` it executes under, and lowering
inserts ``PRepartition`` boundaries exactly where adjacent specs disagree.
Under a row partition every operator body is *unchanged* — each device runs
the ordinary single-device code on its row block; under a hash partition a
join runs on bucket-masked inputs — so partitioned execution is the same
``run_node`` with an ``axis`` name bound inside ``shard_map``
(``core.mesh.shard_replicated``).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Mapping, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core import ir
from repro.core.evaluator import as_column, eval_expr
from repro.mlfuncs.registry import Registry
from repro.relational import ops
from repro.relational.table import Table


# ---------------------------------------------------------------------------
# PartSpec: how one node's rows are split over the mesh's data axis
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PartSpec:
    """Row distribution of one physical node's output.

    kind : 'rep'  — replicated: every device holds all rows (the
                    single-device semantics; the default everywhere).
           'row'  — row blocks: device i holds rows
                    ``[i*ceil(C/ways), (i+1)*ceil(C/ways))`` of the
                    (tail-padded) table; local capacity is the block size.
           'hash' — hash buckets: full capacity everywhere, but device i's
                    valid mask is restricted to rows whose
                    ``hash_bucket(key) == i`` (static shapes make a
                    compacted bucket capacity unsound under skew — all keys
                    may land in one bucket — so bucket partitioning trades
                    no memory for collective-free local joins).
    """
    kind: str = "rep"
    ways: int = 1
    key: Optional[str] = None  # bucket column ('hash' only)

    def signature(self) -> str:
        if self.kind == "rep":
            return "rep"
        tag = f"{self.kind}{self.ways}"
        return tag + (f"[{self.key}]" if self.key else "")


REPLICATED = PartSpec()


# ---------------------------------------------------------------------------
# pipeline stages (row-local, fusable)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FilterStage:
    pred: ir.Expr

    def signature(self) -> str:
        return f"f[{ir._expr_sig(self.pred)}]"


@dataclasses.dataclass(frozen=True)
class ProjectStage:
    outputs: Tuple[Tuple[str, ir.Expr], ...]
    keep: Optional[Tuple[str, ...]] = None

    def signature(self) -> str:
        outs = ",".join(f"{n}={ir._expr_sig(e)}" for n, e in self.outputs)
        return f"p[{outs};{self.keep}]"


@dataclasses.dataclass(frozen=True)
class CompactStage:
    capacity: int

    def signature(self) -> str:
        return f"c[{self.capacity}]"


Stage = Union[FilterStage, ProjectStage, CompactStage]


# ---------------------------------------------------------------------------
# physical operators
# ---------------------------------------------------------------------------

class PhysNode:
    def children(self) -> Tuple["PhysNode", ...]:
        return ()


@dataclasses.dataclass(frozen=True)
class PScan(PhysNode):
    table: str


@dataclasses.dataclass(frozen=True)
class PPipeline(PhysNode):
    child: PhysNode
    stages: Tuple[Stage, ...]

    def children(self):
        return (self.child,)


@dataclasses.dataclass(frozen=True)
class PJoin(PhysNode):
    left: PhysNode
    right: PhysNode
    left_key: str
    right_key: str
    rprefix: str = ""

    def children(self):
        return (self.left, self.right)


@dataclasses.dataclass(frozen=True)
class PCrossJoin(PhysNode):
    left: PhysNode
    right: PhysNode
    aprefix: str = ""
    bprefix: str = ""

    def children(self):
        return (self.left, self.right)


@dataclasses.dataclass(frozen=True)
class PAggregate(PhysNode):
    child: PhysNode
    key: str
    aggs: Tuple[Tuple[str, Tuple[str, str]], ...]
    num_groups: int

    def children(self):
        return (self.child,)


@dataclasses.dataclass(frozen=True)
class PBlockedMatmul(PhysNode):
    child: PhysNode
    x_col: str
    out_col: str
    fn: str
    n_tiles: int
    mode: str          # 'relational' | 'fused'
    backend: str       # 'jnp' | 'pallas'
    keep: Optional[Tuple[str, ...]] = None

    def children(self):
        return (self.child,)


@dataclasses.dataclass(frozen=True)
class PForestRelational(PhysNode):
    child: PhysNode
    x_col: str
    out_col: str
    fn: str
    mode: str
    backend: str
    keep: Optional[Tuple[str, ...]] = None

    def children(self):
        return (self.child,)


@dataclasses.dataclass(frozen=True)
class PRepartition(PhysNode):
    """Partition boundary: convert the child's PartSpec into the consumer's.

    op : 'slice'     — replicated -> row: device i takes its block of the
                       tail-padded table (``out_capacity`` = block size).
      'allgather' — row -> replicated: concatenate all blocks
                       (``jax.lax.all_gather`` tiled) and drop the tail
                       padding back to ``out_capacity`` (the global
                       capacity) — row blocks tile the original row order,
                       so the reassembled table is bit-identical to the
                       unpartitioned one.
      'bucket'    — replicated -> hash: mask validity to the rows whose
                       ``hash_bucket(key) == axis_index``.
      'combine'   — hash -> replicated: zero the rows a device does not
                       own and ``psum`` columns + masks (each valid row is
                       owned by exactly one device, so the sum is exact —
                       including total skew, where one device owns all).
    """
    child: PhysNode
    op: str
    ways: int
    in_capacity: int
    out_capacity: int
    key: Optional[str] = None  # bucket column ('bucket' only)

    def children(self):
        return (self.child,)


@dataclasses.dataclass(frozen=True)
class PhysicalPlan:
    root: PhysNode
    registry: Registry
    # PartSpec side table (mirrors ir.Plan.phys): node tree path -> the
    # spec the node executes under. "r" is the root, "r.0" its first
    # child, ... Empty on unpartitioned plans; purely descriptive at run
    # time (execution follows the explicit PRepartition boundaries).
    parts: Mapping[str, PartSpec] = dataclasses.field(default_factory=dict)
    ways: int = 1  # >1 iff any node's spec is partitioned

    def signature(self) -> str:
        return phys_signature(self.root)

    def part_for(self, path: str) -> PartSpec:
        return self.parts.get(path, REPLICATED)

    def part_signature(self) -> str:
        """The PartSpec vector, compact and stable (cache-key material):
        only non-replicated entries, in tree-path order."""
        items = [f"{p}={s.signature()}" for p, s in sorted(self.parts.items())
                 if s.kind != "rep"]
        return ",".join(items) if items else "rep"


def phys_signature(node: PhysNode) -> str:
    if isinstance(node, PScan):
        return f"S({node.table})"
    if isinstance(node, PPipeline):
        stages = "|".join(s.signature() for s in node.stages)
        return f"PIPE({stages};{phys_signature(node.child)})"
    if isinstance(node, PJoin):
        return (f"J({node.left_key}={node.right_key},"
                f"{phys_signature(node.left)},{phys_signature(node.right)})")
    if isinstance(node, PCrossJoin):
        return f"X({phys_signature(node.left)},{phys_signature(node.right)})"
    if isinstance(node, PAggregate):
        aggs = ",".join(f"{o}={k}:{c}" for o, (k, c) in node.aggs)
        return f"A({node.key};{aggs};{phys_signature(node.child)})"
    if isinstance(node, PBlockedMatmul):
        return (f"BM({node.x_col}->{node.out_col},{node.fn},{node.n_tiles},"
                f"{node.mode},{node.backend},{phys_signature(node.child)})")
    if isinstance(node, PForestRelational):
        return (f"FR({node.x_col}->{node.out_col},{node.fn},{node.mode},"
                f"{node.backend},{phys_signature(node.child)})")
    if isinstance(node, PRepartition):
        return (f"RP({node.op},{node.ways},{node.key},{node.in_capacity}"
                f"->{node.out_capacity},{phys_signature(node.child)})")
    raise TypeError(type(node))


# ---------------------------------------------------------------------------
# realizations of R3-1 / R3-2
# ---------------------------------------------------------------------------

def matmul_weight(registry: Registry, fn_name: str):
    fn = registry.get(fn_name)
    assert fn.graph is not None and len(fn.graph.nodes) == 1
    atom = fn.graph.nodes[0].atom
    assert atom.kind == "matmul", f"{fn_name} is not a pure matmul"
    return jnp.asarray(atom.params["w"])


def blocked_matmul_fused(x: jax.Array, w: jax.Array, n_tiles: int,
                         backend: str) -> jax.Array:
    """Pipelined tile-at-a-time matmul over column blocks of w."""
    if backend == "pallas":
        from repro.kernels.block_matmul import ops as bm_ops
        return bm_ops.block_matmul(x, w, n_tiles)
    dout = w.shape[1]
    tile = -(-dout // n_tiles)  # ceil
    pad = tile * n_tiles - dout
    wp = jnp.pad(w, ((0, 0), (0, pad)))
    tiles = wp.reshape(w.shape[0], n_tiles, tile).transpose(1, 0, 2)  # [T, din, tile]

    def body(carry, wt):
        return carry, x @ wt

    _, blocks = jax.lax.scan(body, 0, tiles)  # [T, N, tile]
    out = blocks.transpose(1, 0, 2).reshape(x.shape[0], n_tiles * tile)
    return out[:, :dout]


def blocked_matmul_relational(t: Table, x_col: str, w: jax.Array,
                              n_tiles: int) -> jax.Array:
    """Literal tensor-relational pipeline (paper Fig. 2):
    tile relation W(colId, tile) -> crossJoin -> project -> assemble.

    The crossJoin is *streamed* one tile at a time (the paper's buffer-pool
    scan / Velox pipelining): each scan step joins T with a single-tile
    relation, projects the per-pair block, and emits it; assembly
    concatenates blocks per rowId. Peak memory is one tile + one block
    column, never the full product.
    """
    din, dout = w.shape
    tile = -(-dout // n_tiles)
    pad = tile * n_tiles - dout
    wp = jnp.pad(w, ((0, 0), (0, pad)))
    tiles = wp.reshape(din, n_tiles, tile).transpose(1, 0, 2)  # [T, din, tile]
    x = t[x_col]

    def scan_tile(_, wt):
        # one-tile relation, crossJoin with T (trivially T rows), project
        one = Table.from_columns({"tile": wt.reshape(1, -1)})
        pairs = ops.cross_join(Table.from_columns({x_col: x}), one)
        wt_full = pairs["tile"].reshape(-1, din, tile)
        yblock = jnp.einsum("nd,ndk->nk", pairs[x_col], wt_full)
        return _, yblock

    _, blocks = jax.lax.scan(scan_tile, 0, tiles)      # [T, N, tile]
    out = blocks.transpose(1, 0, 2).reshape(t.capacity, n_tiles * tile)
    return out[:, :dout]


def forest_fused(x: jax.Array, fn, backend: str) -> jax.Array:
    atom = fn.graph.nodes[0].atom
    if backend == "pallas":
        from repro.kernels.decision_forest import ops as df_ops
        p = atom.params
        return df_ops.forest_predict(x, jnp.asarray(p["feat"]),
                                     jnp.asarray(p["thresh"]),
                                     jnp.asarray(p["leaf"]))
    return atom.apply(x)


def forest_relational(t: Table, x_col: str, fn) -> jax.Array:
    """crossJoin(T, DF) -> project t.predict(x) -> aggregate mean by row.

    Streamed one tree at a time (buffer-pool scan over the DF relation):
    each step joins T with a single-tree relation, projects the per-pair
    prediction, and the running aggregate accumulates the vote.
    """
    p = fn.graph.nodes[0].atom.params
    feat = jnp.asarray(p["feat"])
    thresh = jnp.asarray(p["thresh"])
    leaf = jnp.asarray(p["leaf"])
    depth = int(p["depth"])
    n_trees = feat.shape[0]
    x = t[x_col]

    def scan_tree(acc, tree):
        f, th, lv = tree
        one = Table.from_columns({"feat": f[None], "thresh": th[None], "leaf": lv[None]})
        pairs = ops.cross_join(Table.from_columns({x_col: x}), one)
        xp, fp, tp, lp = pairs[x_col], pairs["feat"], pairs["thresh"], pairs["leaf"]
        node = jnp.zeros((xp.shape[0],), jnp.int32)
        for _ in range(depth):
            fi = jnp.take_along_axis(fp, node[:, None], axis=1)[:, 0]
            ti = jnp.take_along_axis(tp, node[:, None], axis=1)[:, 0]
            xv = jnp.take_along_axis(xp, fi[:, None], axis=1)[:, 0]
            node = 2 * node + 1 + (xv > ti).astype(jnp.int32)
        leaf_idx = node - (2 ** depth - 1)
        pred = jnp.take_along_axis(lp, leaf_idx[:, None], axis=1)[:, 0]
        return acc + pred, None

    acc, _ = jax.lax.scan(scan_tree, jnp.zeros((x.shape[0],), jnp.float32),
                          (feat, thresh, leaf))
    return acc / n_trees


# ---------------------------------------------------------------------------
# repartition boundaries (shard_map collectives)
# ---------------------------------------------------------------------------

def _pad_rows(x: jax.Array, n: int):
    """Append ``n`` zero rows (False for the valid mask) at the tail."""
    if n <= 0:
        return x
    return jnp.pad(x, ((0, n),) + ((0, 0),) * (x.ndim - 1))


def run_repartition(node: PRepartition, t: Table, axis: Optional[str]) -> Table:
    from repro.core import mesh as mesh_util

    if axis is None:
        raise RuntimeError(
            f"PRepartition({node.op}) needs a mesh axis: partitioned plans "
            "execute inside shard_map (core.mesh.shard_replicated) — see "
            "PlanCache.get_or_compile_partitioned")
    i = jax.lax.axis_index(axis)
    if node.op == "slice":
        block = node.out_capacity
        pad = block * node.ways - t.capacity

        def sl(x):
            return jax.lax.dynamic_slice_in_dim(_pad_rows(x, pad), i * block,
                                                block, axis=0)

        return Table(columns={k: sl(v) for k, v in t.columns.items()},
                     valid=sl(t.valid))
    if node.op == "allgather":
        # blocks tile the (tail-padded) original row order: concatenating
        # them and slicing off the padding restores the exact global table
        def ag(x):
            return jax.lax.all_gather(x, axis, axis=0,
                                      tiled=True)[:node.out_capacity]

        return Table(columns={k: ag(v) for k, v in t.columns.items()},
                     valid=ag(t.valid))
    if node.op == "bucket":
        own = mesh_util.hash_bucket(t[node.key], node.ways) == i
        return Table(columns=t.columns, valid=t.valid & own)
    if node.op == "combine":
        # each valid row is owned by exactly one device: zero the rest and
        # psum — exact for ints, and exact for floats too (x + 0.0 == x)
        def cb(x):
            m = t.valid.reshape((-1,) + (1,) * (x.ndim - 1))
            return jax.lax.psum(jnp.where(m, x, jnp.zeros((), x.dtype)), axis)

        valid = jax.lax.psum(t.valid.astype(jnp.int32), axis) > 0
        return Table(columns={k: cb(v) for k, v in t.columns.items()},
                     valid=valid)
    raise ValueError(f"unknown repartition op {node.op!r}")


# ---------------------------------------------------------------------------
# physical execution
# ---------------------------------------------------------------------------

def _run_stage(stage: Stage, t: Table, registry: Registry) -> Table:
    if isinstance(stage, FilterStage):
        mask = jnp.asarray(eval_expr(stage.pred, t, registry)).astype(bool)
        mask = as_column(mask, t.capacity)
        return ops.filter_(t, mask)
    if isinstance(stage, ProjectStage):
        new_cols = {name: as_column(eval_expr(e, t, registry), t.capacity)
                    for name, e in stage.outputs}
        return ops.project(t, new_cols, keep=stage.keep)
    if isinstance(stage, CompactStage):
        return ops.compact(t, stage.capacity)
    raise TypeError(type(stage))


def run_node(node: PhysNode, tables: Dict[str, Table],
             registry: Registry, axis: Optional[str] = None) -> Table:
    if isinstance(node, PScan):
        return tables[node.table]
    if isinstance(node, PPipeline):
        t = run_node(node.child, tables, registry, axis)
        for stage in node.stages:
            t = _run_stage(stage, t, registry)
        return t
    if isinstance(node, PJoin):
        lt = run_node(node.left, tables, registry, axis)
        rt = run_node(node.right, tables, registry, axis)
        return ops.fk_join(lt, rt, node.left_key, node.right_key, node.rprefix)
    if isinstance(node, PCrossJoin):
        lt = run_node(node.left, tables, registry, axis)
        rt = run_node(node.right, tables, registry, axis)
        return ops.cross_join(lt, rt, node.aprefix, node.bprefix)
    if isinstance(node, PAggregate):
        t = run_node(node.child, tables, registry, axis)
        return ops.aggregate(t, node.key, dict(node.aggs), node.num_groups)
    if isinstance(node, PBlockedMatmul):
        t = run_node(node.child, tables, registry, axis)
        w = matmul_weight(registry, node.fn)
        if node.mode == "relational":
            y = blocked_matmul_relational(t, node.x_col, w, node.n_tiles)
        else:
            y = blocked_matmul_fused(t[node.x_col], w, node.n_tiles, node.backend)
        return ops.project(t, {node.out_col: y}, keep=node.keep)
    if isinstance(node, PForestRelational):
        t = run_node(node.child, tables, registry, axis)
        fn = registry.get(node.fn)
        if node.mode == "relational":
            y = forest_relational(t, node.x_col, fn)
        else:
            y = forest_fused(t[node.x_col], fn, node.backend)
        return ops.project(t, {node.out_col: y}, keep=node.keep)
    if isinstance(node, PRepartition):
        t = run_node(node.child, tables, registry, axis)
        return run_repartition(node, t, axis)
    raise TypeError(type(node))


def run(pplan: PhysicalPlan, tables: Dict[str, Table],
        axis: Optional[str] = None) -> Table:
    """Execute a physical plan. ``axis`` names the shard_map mesh axis a
    *partitioned* plan's repartition boundaries collect over; unpartitioned
    plans (no PRepartition nodes) ignore it."""
    return run_node(pplan.root, tables, pplan.registry, axis)
