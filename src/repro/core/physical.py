"""Physical plan layer: operators the backend actually runs.

Produced from the logical IR by ``repro.core.lowering.lower``; executed by
``run`` below. Physical nodes are where realization choices live — the
logical tree never carries mode/backend/tile decisions (those are ``ir.Plan``
side-table annotations consumed at lowering time).

Operators:
  PScan            — catalog table lookup.
  PPipeline        — a fused chain of row-local stages (Filter / Project /
                     Compact), executed one table pass per stage without
                     per-node interpreter dispatch (Velox-style driver).
  PJoin/PCrossJoin — relational joins (repro.relational.ops).
  PAggregate       — group-by.
  PBlockedMatmul   — R3-1 realization: 'relational' streams the weight-tile
                     relation (paper Fig. 2); 'fused' is the pipelined blocked
                     matmul; backend 'pallas' uses the TPU kernel.
  PForestRelational— R3-2 realization: 'relational' streams the tree relation;
                     'fused' evaluates the ensemble per row.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core import ir
from repro.core.evaluator import as_column, eval_expr
from repro.mlfuncs.registry import Registry
from repro.relational import ops
from repro.relational.table import Table


# ---------------------------------------------------------------------------
# pipeline stages (row-local, fusable)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FilterStage:
    pred: ir.Expr

    def signature(self) -> str:
        return f"f[{ir._expr_sig(self.pred)}]"


@dataclasses.dataclass(frozen=True)
class ProjectStage:
    outputs: Tuple[Tuple[str, ir.Expr], ...]
    keep: Optional[Tuple[str, ...]] = None

    def signature(self) -> str:
        outs = ",".join(f"{n}={ir._expr_sig(e)}" for n, e in self.outputs)
        return f"p[{outs};{self.keep}]"


@dataclasses.dataclass(frozen=True)
class CompactStage:
    capacity: int

    def signature(self) -> str:
        return f"c[{self.capacity}]"


Stage = Union[FilterStage, ProjectStage, CompactStage]


# ---------------------------------------------------------------------------
# physical operators
# ---------------------------------------------------------------------------

class PhysNode:
    def children(self) -> Tuple["PhysNode", ...]:
        return ()


@dataclasses.dataclass(frozen=True)
class PScan(PhysNode):
    table: str


@dataclasses.dataclass(frozen=True)
class PPipeline(PhysNode):
    child: PhysNode
    stages: Tuple[Stage, ...]

    def children(self):
        return (self.child,)


@dataclasses.dataclass(frozen=True)
class PJoin(PhysNode):
    left: PhysNode
    right: PhysNode
    left_key: str
    right_key: str
    rprefix: str = ""

    def children(self):
        return (self.left, self.right)


@dataclasses.dataclass(frozen=True)
class PCrossJoin(PhysNode):
    left: PhysNode
    right: PhysNode
    aprefix: str = ""
    bprefix: str = ""

    def children(self):
        return (self.left, self.right)


@dataclasses.dataclass(frozen=True)
class PAggregate(PhysNode):
    child: PhysNode
    key: str
    aggs: Tuple[Tuple[str, Tuple[str, str]], ...]
    num_groups: int

    def children(self):
        return (self.child,)


@dataclasses.dataclass(frozen=True)
class PBlockedMatmul(PhysNode):
    child: PhysNode
    x_col: str
    out_col: str
    fn: str
    n_tiles: int
    mode: str          # 'relational' | 'fused'
    backend: str       # 'jnp' | 'pallas'
    keep: Optional[Tuple[str, ...]] = None

    def children(self):
        return (self.child,)


@dataclasses.dataclass(frozen=True)
class PForestRelational(PhysNode):
    child: PhysNode
    x_col: str
    out_col: str
    fn: str
    mode: str
    backend: str
    keep: Optional[Tuple[str, ...]] = None

    def children(self):
        return (self.child,)


@dataclasses.dataclass(frozen=True)
class PhysicalPlan:
    root: PhysNode
    registry: Registry

    def signature(self) -> str:
        return phys_signature(self.root)


def phys_signature(node: PhysNode) -> str:
    if isinstance(node, PScan):
        return f"S({node.table})"
    if isinstance(node, PPipeline):
        stages = "|".join(s.signature() for s in node.stages)
        return f"PIPE({stages};{phys_signature(node.child)})"
    if isinstance(node, PJoin):
        return (f"J({node.left_key}={node.right_key},"
                f"{phys_signature(node.left)},{phys_signature(node.right)})")
    if isinstance(node, PCrossJoin):
        return f"X({phys_signature(node.left)},{phys_signature(node.right)})"
    if isinstance(node, PAggregate):
        aggs = ",".join(f"{o}={k}:{c}" for o, (k, c) in node.aggs)
        return f"A({node.key};{aggs};{phys_signature(node.child)})"
    if isinstance(node, PBlockedMatmul):
        return (f"BM({node.x_col}->{node.out_col},{node.fn},{node.n_tiles},"
                f"{node.mode},{node.backend},{phys_signature(node.child)})")
    if isinstance(node, PForestRelational):
        return (f"FR({node.x_col}->{node.out_col},{node.fn},{node.mode},"
                f"{node.backend},{phys_signature(node.child)})")
    raise TypeError(type(node))


# ---------------------------------------------------------------------------
# realizations of R3-1 / R3-2
# ---------------------------------------------------------------------------

def matmul_weight(registry: Registry, fn_name: str):
    fn = registry.get(fn_name)
    assert fn.graph is not None and len(fn.graph.nodes) == 1
    atom = fn.graph.nodes[0].atom
    assert atom.kind == "matmul", f"{fn_name} is not a pure matmul"
    return jnp.asarray(atom.params["w"])


def blocked_matmul_fused(x: jax.Array, w: jax.Array, n_tiles: int,
                         backend: str) -> jax.Array:
    """Pipelined tile-at-a-time matmul over column blocks of w."""
    if backend == "pallas":
        from repro.kernels.block_matmul import ops as bm_ops
        return bm_ops.block_matmul(x, w, n_tiles)
    dout = w.shape[1]
    tile = -(-dout // n_tiles)  # ceil
    pad = tile * n_tiles - dout
    wp = jnp.pad(w, ((0, 0), (0, pad)))
    tiles = wp.reshape(w.shape[0], n_tiles, tile).transpose(1, 0, 2)  # [T, din, tile]

    def body(carry, wt):
        return carry, x @ wt

    _, blocks = jax.lax.scan(body, 0, tiles)  # [T, N, tile]
    out = blocks.transpose(1, 0, 2).reshape(x.shape[0], n_tiles * tile)
    return out[:, :dout]


def blocked_matmul_relational(t: Table, x_col: str, w: jax.Array,
                              n_tiles: int) -> jax.Array:
    """Literal tensor-relational pipeline (paper Fig. 2):
    tile relation W(colId, tile) -> crossJoin -> project -> assemble.

    The crossJoin is *streamed* one tile at a time (the paper's buffer-pool
    scan / Velox pipelining): each scan step joins T with a single-tile
    relation, projects the per-pair block, and emits it; assembly
    concatenates blocks per rowId. Peak memory is one tile + one block
    column, never the full product.
    """
    din, dout = w.shape
    tile = -(-dout // n_tiles)
    pad = tile * n_tiles - dout
    wp = jnp.pad(w, ((0, 0), (0, pad)))
    tiles = wp.reshape(din, n_tiles, tile).transpose(1, 0, 2)  # [T, din, tile]
    x = t[x_col]

    def scan_tile(_, wt):
        # one-tile relation, crossJoin with T (trivially T rows), project
        one = Table.from_columns({"tile": wt.reshape(1, -1)})
        pairs = ops.cross_join(Table.from_columns({x_col: x}), one)
        wt_full = pairs["tile"].reshape(-1, din, tile)
        yblock = jnp.einsum("nd,ndk->nk", pairs[x_col], wt_full)
        return _, yblock

    _, blocks = jax.lax.scan(scan_tile, 0, tiles)      # [T, N, tile]
    out = blocks.transpose(1, 0, 2).reshape(t.capacity, n_tiles * tile)
    return out[:, :dout]


def forest_fused(x: jax.Array, fn, backend: str) -> jax.Array:
    atom = fn.graph.nodes[0].atom
    if backend == "pallas":
        from repro.kernels.decision_forest import ops as df_ops
        p = atom.params
        return df_ops.forest_predict(x, jnp.asarray(p["feat"]),
                                     jnp.asarray(p["thresh"]),
                                     jnp.asarray(p["leaf"]))
    return atom.apply(x)


def forest_relational(t: Table, x_col: str, fn) -> jax.Array:
    """crossJoin(T, DF) -> project t.predict(x) -> aggregate mean by row.

    Streamed one tree at a time (buffer-pool scan over the DF relation):
    each step joins T with a single-tree relation, projects the per-pair
    prediction, and the running aggregate accumulates the vote.
    """
    p = fn.graph.nodes[0].atom.params
    feat = jnp.asarray(p["feat"])
    thresh = jnp.asarray(p["thresh"])
    leaf = jnp.asarray(p["leaf"])
    depth = int(p["depth"])
    n_trees = feat.shape[0]
    x = t[x_col]

    def scan_tree(acc, tree):
        f, th, lv = tree
        one = Table.from_columns({"feat": f[None], "thresh": th[None], "leaf": lv[None]})
        pairs = ops.cross_join(Table.from_columns({x_col: x}), one)
        xp, fp, tp, lp = pairs[x_col], pairs["feat"], pairs["thresh"], pairs["leaf"]
        node = jnp.zeros((xp.shape[0],), jnp.int32)
        for _ in range(depth):
            fi = jnp.take_along_axis(fp, node[:, None], axis=1)[:, 0]
            ti = jnp.take_along_axis(tp, node[:, None], axis=1)[:, 0]
            xv = jnp.take_along_axis(xp, fi[:, None], axis=1)[:, 0]
            node = 2 * node + 1 + (xv > ti).astype(jnp.int32)
        leaf_idx = node - (2 ** depth - 1)
        pred = jnp.take_along_axis(lp, leaf_idx[:, None], axis=1)[:, 0]
        return acc + pred, None

    acc, _ = jax.lax.scan(scan_tree, jnp.zeros((x.shape[0],), jnp.float32),
                          (feat, thresh, leaf))
    return acc / n_trees


# ---------------------------------------------------------------------------
# physical execution
# ---------------------------------------------------------------------------

def _run_stage(stage: Stage, t: Table, registry: Registry) -> Table:
    if isinstance(stage, FilterStage):
        mask = jnp.asarray(eval_expr(stage.pred, t, registry)).astype(bool)
        mask = as_column(mask, t.capacity)
        return ops.filter_(t, mask)
    if isinstance(stage, ProjectStage):
        new_cols = {name: as_column(eval_expr(e, t, registry), t.capacity)
                    for name, e in stage.outputs}
        return ops.project(t, new_cols, keep=stage.keep)
    if isinstance(stage, CompactStage):
        return ops.compact(t, stage.capacity)
    raise TypeError(type(stage))


def run_node(node: PhysNode, tables: Dict[str, Table],
             registry: Registry) -> Table:
    if isinstance(node, PScan):
        return tables[node.table]
    if isinstance(node, PPipeline):
        t = run_node(node.child, tables, registry)
        for stage in node.stages:
            t = _run_stage(stage, t, registry)
        return t
    if isinstance(node, PJoin):
        lt = run_node(node.left, tables, registry)
        rt = run_node(node.right, tables, registry)
        return ops.fk_join(lt, rt, node.left_key, node.right_key, node.rprefix)
    if isinstance(node, PCrossJoin):
        lt = run_node(node.left, tables, registry)
        rt = run_node(node.right, tables, registry)
        return ops.cross_join(lt, rt, node.aprefix, node.bprefix)
    if isinstance(node, PAggregate):
        t = run_node(node.child, tables, registry)
        return ops.aggregate(t, node.key, dict(node.aggs), node.num_groups)
    if isinstance(node, PBlockedMatmul):
        t = run_node(node.child, tables, registry)
        w = matmul_weight(registry, node.fn)
        if node.mode == "relational":
            y = blocked_matmul_relational(t, node.x_col, w, node.n_tiles)
        else:
            y = blocked_matmul_fused(t[node.x_col], w, node.n_tiles, node.backend)
        return ops.project(t, {node.out_col: y}, keep=node.keep)
    if isinstance(node, PForestRelational):
        t = run_node(node.child, tables, registry)
        fn = registry.get(node.fn)
        if node.mode == "relational":
            y = forest_relational(t, node.x_col, fn)
        else:
            y = forest_fused(t[node.x_col], fn, node.backend)
        return ops.project(t, {node.out_col: y}, keep=node.keep)
    raise TypeError(type(node))


def run(pplan: PhysicalPlan, tables: Dict[str, Table]) -> Table:
    return run_node(pplan.root, tables, pplan.registry)
