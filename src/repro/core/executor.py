"""Plan executor: compiles a Plan into a (jit-able) function Catalog → Table.

The executor is the physical layer: relational operators map to
repro.relational.ops; BlockedMatmul / ForestRelational (R3-1 / R3-2 physical
nodes) support both a literal 'relational' realization (tile/tree relations +
crossJoin + project + assemble, paper Fig. 2) and a pipelined 'fused'
realization (Velox-style, no materialized product; 'pallas' backend uses the
TPU kernels).
"""
from __future__ import annotations

import functools
from typing import Dict

import jax
import jax.numpy as jnp

from repro.core import ir
from repro.mlfuncs.registry import Registry
from repro.relational import ops
from repro.relational.table import Table


# ---------------------------------------------------------------------------
# expression evaluation (middle-level IR)
# ---------------------------------------------------------------------------

def eval_expr(e: ir.Expr, t: Table, registry: Registry) -> jax.Array:
    if isinstance(e, ir.Col):
        return t[e.name]
    if isinstance(e, ir.Const):
        return jnp.full((t.capacity,), float(e.value), jnp.float32)
    if isinstance(e, ir.BinOp):
        a, b = eval_expr(e.a, t, registry), eval_expr(e.b, t, registry)
        a, b = _align(a, b)
        if e.op == "+":
            return a + b
        if e.op == "-":
            return a - b
        if e.op == "*":
            return a * b
        if e.op == "/":
            return a / jnp.where(b == 0, 1e-9, b)
        raise ValueError(e.op)
    if isinstance(e, ir.Cmp):
        a, b = eval_expr(e.a, t, registry), eval_expr(e.b, t, registry)
        a, b = _align(a, b)
        return {"<": a < b, ">": a > b, "<=": a <= b, ">=": a >= b,
                "==": a == b, "!=": a != b}[e.op]
    if isinstance(e, ir.BoolOp):
        vals = [eval_expr(a, t, registry).astype(bool) for a in e.args]
        if e.op == "and":
            return functools.reduce(jnp.logical_and, vals)
        if e.op == "or":
            return functools.reduce(jnp.logical_or, vals)
        if e.op == "not":
            return jnp.logical_not(vals[0])
        raise ValueError(e.op)
    if isinstance(e, ir.IsIn):
        a = eval_expr(e.a, t, registry).astype(jnp.int32)
        out = jnp.zeros_like(a, dtype=bool)
        for v in e.values:
            out = out | (a == v)
        return out
    if isinstance(e, ir.IfExpr):
        c = eval_expr(e.cond, t, registry).astype(bool)
        return jnp.where(c, eval_expr(e.t, t, registry), eval_expr(e.f, t, registry))
    if isinstance(e, ir.Call):
        fn = registry.get(e.fn)
        args = [eval_expr(a, t, registry) for a in e.args]
        out = fn.apply(*args)
        if out.ndim == 2 and out.shape[1] == 1:
            out = out[:, 0]  # dim-1 vectors are scalar columns
        return out
    raise TypeError(type(e))


def _align(a, b):
    if a.ndim == 2 and b.ndim == 1:
        return a, b[:, None]
    if a.ndim == 1 and b.ndim == 2:
        return a[:, None], b
    return a, b


# ---------------------------------------------------------------------------
# physical realizations of R3-1 / R3-2
# ---------------------------------------------------------------------------

def _matmul_weight(registry: Registry, fn_name: str):
    fn = registry.get(fn_name)
    assert fn.graph is not None and len(fn.graph.nodes) == 1
    atom = fn.graph.nodes[0].atom
    assert atom.kind == "matmul", f"{fn_name} is not a pure matmul"
    return jnp.asarray(atom.params["w"])


def blocked_matmul_fused(x: jax.Array, w: jax.Array, n_tiles: int,
                         backend: str) -> jax.Array:
    """Pipelined tile-at-a-time matmul over column blocks of w."""
    if backend == "pallas":
        from repro.kernels.block_matmul import ops as bm_ops
        return bm_ops.block_matmul(x, w, n_tiles)
    dout = w.shape[1]
    tile = -(-dout // n_tiles)  # ceil
    pad = tile * n_tiles - dout
    wp = jnp.pad(w, ((0, 0), (0, pad)))
    tiles = wp.reshape(w.shape[0], n_tiles, tile).transpose(1, 0, 2)  # [T, din, tile]

    def body(carry, wt):
        return carry, x @ wt

    _, blocks = jax.lax.scan(body, 0, tiles)  # [T, N, tile]
    out = blocks.transpose(1, 0, 2).reshape(x.shape[0], n_tiles * tile)
    return out[:, :dout]


def blocked_matmul_relational(t: Table, x_col: str, w: jax.Array,
                              n_tiles: int) -> jax.Array:
    """Literal tensor-relational pipeline (paper Fig. 2):
    tile relation W(colId, tile) -> crossJoin -> project -> assemble.

    The crossJoin is *streamed* one tile at a time (the paper's buffer-pool
    scan / Velox pipelining): each scan step joins T with a single-tile
    relation, projects the per-pair block, and emits it; assembly
    concatenates blocks per rowId. Peak memory is one tile + one block
    column, never the full product.
    """
    din, dout = w.shape
    tile = -(-dout // n_tiles)
    pad = tile * n_tiles - dout
    wp = jnp.pad(w, ((0, 0), (0, pad)))
    tiles = wp.reshape(din, n_tiles, tile).transpose(1, 0, 2)  # [T, din, tile]
    x = t[x_col]

    def scan_tile(_, wt):
        # one-tile relation, crossJoin with T (trivially T rows), project
        one = Table.from_columns({"tile": wt.reshape(1, -1)})
        pairs = ops.cross_join(Table.from_columns({x_col: x}), one)
        wt_full = pairs["tile"].reshape(-1, din, tile)
        yblock = jnp.einsum("nd,ndk->nk", pairs[x_col], wt_full)
        return _, yblock

    _, blocks = jax.lax.scan(scan_tile, 0, tiles)      # [T, N, tile]
    out = blocks.transpose(1, 0, 2).reshape(t.capacity, n_tiles * tile)
    return out[:, :dout]


def forest_fused(x: jax.Array, fn, backend: str) -> jax.Array:
    atom = fn.graph.nodes[0].atom
    if backend == "pallas":
        from repro.kernels.decision_forest import ops as df_ops
        p = atom.params
        return df_ops.forest_predict(x, jnp.asarray(p["feat"]),
                                     jnp.asarray(p["thresh"]),
                                     jnp.asarray(p["leaf"]))
    return atom.apply(x)


def forest_relational(t: Table, x_col: str, fn) -> jax.Array:
    """crossJoin(T, DF) -> project t.predict(x) -> aggregate mean by row.

    Streamed one tree at a time (buffer-pool scan over the DF relation):
    each step joins T with a single-tree relation, projects the per-pair
    prediction, and the running aggregate accumulates the vote.
    """
    p = fn.graph.nodes[0].atom.params
    feat = jnp.asarray(p["feat"])
    thresh = jnp.asarray(p["thresh"])
    leaf = jnp.asarray(p["leaf"])
    depth = int(p["depth"])
    n_trees = feat.shape[0]
    x = t[x_col]

    def scan_tree(acc, tree):
        f, th, lv = tree
        one = Table.from_columns({"feat": f[None], "thresh": th[None], "leaf": lv[None]})
        pairs = ops.cross_join(Table.from_columns({x_col: x}), one)
        xp, fp, tp, lp = pairs[x_col], pairs["feat"], pairs["thresh"], pairs["leaf"]
        node = jnp.zeros((xp.shape[0],), jnp.int32)
        for _ in range(depth):
            fi = jnp.take_along_axis(fp, node[:, None], axis=1)[:, 0]
            ti = jnp.take_along_axis(tp, node[:, None], axis=1)[:, 0]
            xv = jnp.take_along_axis(xp, fi[:, None], axis=1)[:, 0]
            node = 2 * node + 1 + (xv > ti).astype(jnp.int32)
        leaf_idx = node - (2 ** depth - 1)
        pred = jnp.take_along_axis(lp, leaf_idx[:, None], axis=1)[:, 0]
        return acc + pred, None

    acc, _ = jax.lax.scan(scan_tree, jnp.zeros((x.shape[0],), jnp.float32),
                          (feat, thresh, leaf))
    return acc / n_trees


# ---------------------------------------------------------------------------
# plan execution
# ---------------------------------------------------------------------------

def execute_node(node: ir.RelNode, catalog_tables: Dict[str, Table],
                 registry: Registry) -> Table:
    if isinstance(node, ir.Scan):
        return catalog_tables[node.table]
    if isinstance(node, ir.Filter):
        t = execute_node(node.child, catalog_tables, registry)
        mask = eval_expr(node.pred, t, registry).astype(bool)
        return ops.filter_(t, mask)
    if isinstance(node, ir.Compact):
        t = execute_node(node.child, catalog_tables, registry)
        return ops.compact(t, node.capacity)
    if isinstance(node, ir.Project):
        t = execute_node(node.child, catalog_tables, registry)
        new_cols = {name: eval_expr(e, t, registry) for name, e in node.outputs}
        return ops.project(t, new_cols, keep=node.keep)
    if isinstance(node, ir.Join):
        lt = execute_node(node.left, catalog_tables, registry)
        rt = execute_node(node.right, catalog_tables, registry)
        return ops.fk_join(lt, rt, node.left_key, node.right_key, node.rprefix)
    if isinstance(node, ir.CrossJoin):
        lt = execute_node(node.left, catalog_tables, registry)
        rt = execute_node(node.right, catalog_tables, registry)
        return ops.cross_join(lt, rt, node.aprefix, node.bprefix)
    if isinstance(node, ir.Aggregate):
        t = execute_node(node.child, catalog_tables, registry)
        return ops.aggregate(t, node.key, dict(node.aggs), node.num_groups)
    if isinstance(node, ir.BlockedMatmul):
        t = execute_node(node.child, catalog_tables, registry)
        w = _matmul_weight(registry, node.fn)
        if node.mode == "relational":
            y = blocked_matmul_relational(t, node.x_col, w, node.n_tiles)
        else:
            y = blocked_matmul_fused(t[node.x_col], w, node.n_tiles, node.backend)
        return ops.project(t, {node.out_col: y}, keep=node.keep)
    if isinstance(node, ir.ForestRelational):
        t = execute_node(node.child, catalog_tables, registry)
        fn = registry.get(node.fn)
        if node.mode == "relational":
            y = forest_relational(t, node.x_col, fn)
        else:
            y = forest_fused(t[node.x_col], fn, node.backend)
        return ops.project(t, {node.out_col: y}, keep=node.keep)
    raise TypeError(type(node))


def execute(plan: ir.Plan, catalog: ir.Catalog) -> Table:
    return execute_node(plan.root, catalog.tables, plan.registry)


def compile_plan(plan: ir.Plan, catalog: ir.Catalog):
    """Returns a jitted zero-arg callable closing over catalog tables."""
    tables = dict(catalog.tables)

    @jax.jit
    def run():
        return execute_node(plan.root, tables, plan.registry)

    return run
