"""Plan execution façade.

The default path is the physical one: ``execute`` lowers the logical plan
(repro.core.lowering) and runs the physical operators (repro.core.physical);
``compile_plan`` goes through the compiled-plan cache
(repro.core.plan_cache), so structurally repeated queries skip lowering and
jax tracing entirely.

``execute_reference`` keeps the original per-node recursive interpreter over
the *logical* tree: the oracle for lowering-equivalence tests. It shares the
expression evaluator and the R3 realization kernels with the physical path
(those are covered separately by tests/test_kernels.py against the ref
implementations); what it does NOT share — and therefore what the
equivalence tests actually check — is the lowering, pipeline fusion, and
side-table plumbing.
"""
from __future__ import annotations

from typing import Dict, Mapping, Optional

import jax.numpy as jnp

from repro.core import ir
from repro.core import physical as ph
from repro.core.evaluator import as_column, eval_expr
from repro.core.lowering import lower
from repro.core.plan_cache import GLOBAL_PLAN_CACHE, PlanCache
from repro.mlfuncs.registry import Registry
from repro.relational import ops
from repro.relational.table import Table


# ---------------------------------------------------------------------------
# default path: lower + run physical
# ---------------------------------------------------------------------------

def execute(plan: ir.Plan, catalog: ir.Catalog, *,
            backend: Optional[str] = None) -> Table:
    return ph.run(lower(plan, catalog, backend=backend), dict(catalog.tables))


def compile_plan(plan: ir.Plan, catalog: ir.Catalog,
                 cache: Optional[PlanCache] = None):
    """Returns a jitted zero-arg callable over the catalog's tables.

    Compilation (lowering + tracing) is shared through the plan cache; the
    returned closure re-reads ``catalog.tables`` on every call, so updated
    table contents (same schema/shapes) flow through without a retrace.
    """
    cache = cache or GLOBAL_PLAN_CACHE
    run = cache.get_or_compile(plan, catalog)
    return lambda: run(dict(catalog.tables))


# ---------------------------------------------------------------------------
# reference interpreter (logical tree, one dispatch per node)
# ---------------------------------------------------------------------------

def execute_node(node: ir.RelNode, catalog_tables: Dict[str, Table],
                 registry: Registry,
                 phys: Optional[Mapping[str, ir.PhysConfig]] = None) -> Table:
    phys = phys or {}
    if isinstance(node, ir.Scan):
        return catalog_tables[node.table]
    if isinstance(node, ir.Filter):
        t = execute_node(node.child, catalog_tables, registry, phys)
        mask = jnp.asarray(eval_expr(node.pred, t, registry)).astype(bool)
        return ops.filter_(t, as_column(mask, t.capacity))
    if isinstance(node, ir.Compact):
        t = execute_node(node.child, catalog_tables, registry, phys)
        return ops.compact(t, node.capacity)
    if isinstance(node, ir.Project):
        t = execute_node(node.child, catalog_tables, registry, phys)
        new_cols = {name: as_column(eval_expr(e, t, registry), t.capacity)
                    for name, e in node.outputs}
        return ops.project(t, new_cols, keep=node.keep)
    if isinstance(node, ir.Join):
        lt = execute_node(node.left, catalog_tables, registry, phys)
        rt = execute_node(node.right, catalog_tables, registry, phys)
        return ops.fk_join(lt, rt, node.left_key, node.right_key, node.rprefix)
    if isinstance(node, ir.CrossJoin):
        lt = execute_node(node.left, catalog_tables, registry, phys)
        rt = execute_node(node.right, catalog_tables, registry, phys)
        return ops.cross_join(lt, rt, node.aprefix, node.bprefix)
    if isinstance(node, ir.Aggregate):
        t = execute_node(node.child, catalog_tables, registry, phys)
        return ops.aggregate(t, node.key, dict(node.aggs), node.num_groups)
    if isinstance(node, ir.BlockedMatmul):
        t = execute_node(node.child, catalog_tables, registry, phys)
        cfg = ir.resolve_phys(node, phys, registry)
        w = ph.matmul_weight(registry, node.fn)
        if cfg.mode == "relational":
            y = ph.blocked_matmul_relational(t, node.x_col, w, cfg.n_tiles)
        else:
            y = ph.blocked_matmul_fused(t[node.x_col], w, cfg.n_tiles,
                                        cfg.backend)
        return ops.project(t, {node.out_col: y}, keep=node.keep)
    if isinstance(node, ir.ForestRelational):
        t = execute_node(node.child, catalog_tables, registry, phys)
        cfg = ir.resolve_phys(node, phys, registry)
        fn = registry.get(node.fn)
        if cfg.mode == "relational":
            y = ph.forest_relational(t, node.x_col, fn)
        else:
            y = ph.forest_fused(t[node.x_col], fn, cfg.backend)
        return ops.project(t, {node.out_col: y}, keep=node.keep)
    raise TypeError(type(node))


def execute_reference(plan: ir.Plan, catalog: ir.Catalog) -> Table:
    return execute_node(plan.root, catalog.tables, plan.registry, plan.phys)
