"""Costed lowering: pick the min-cost physical realization of a plan.

Phase 2 of the two-phase lowering pipeline: ``stage_graph.build`` (phase 1)
turns the logical plan into a stage-DAG of open decisions — stage order
within each fused pipeline, compaction placement after selective filters,
mode/backend realization per un-annotated ML node — and this module
enumerates the bounded candidate set and scores every realized candidate
through the *shared* cost oracle ``cost.plan_cost`` (the same entry point
the MCTS optimizers reward against; see ``planner.analytic_cost_fn``).

Enumeration is exhaustive over the cartesian product of site options while
it fits in ``max_candidates``; beyond that it falls back to deterministic
coordinate descent (two sweeps over the sites, committing the best option
of each site against the current best decisions). Deviating from the
tree-order default requires a *strictly* cheaper candidate, so plans the
oracle cannot separate keep the heuristic lowering (and its cache keys).

``choose_batch_realization`` is the same oracle applied to the serving
tier's vmapped-vs-sharded choice for one micro-batch.
"""
from __future__ import annotations

import dataclasses
import itertools
import logging
import math
from typing import Dict, Optional

from repro.core import cost, ir, stage_graph
from repro.core import physical as ph

MAX_CANDIDATES = 64

logger = logging.getLogger(__name__)


@dataclasses.dataclass
class Lowered:
    """A costed lowering result: the chosen physical plan plus the decision
    vector that produced it (``signature`` is the plan-cache key part).

    ``budget_pruned`` counts candidates the per-device memory budget
    hard-rejected; ``budget_pruned_all`` is the misconfiguration flag —
    *every* scored candidate (including the partitioned ones) busted the
    budget and lowering fell back to tree order, so the chosen plan does
    NOT fit. Surfacing it here (plus a log line) keeps a too-small budget
    visible instead of silently degrading to arbitrary plans."""
    plan: ph.PhysicalPlan
    decisions: Dict[str, int]
    signature: str
    cost: float
    baseline_cost: float     # tree-order (heuristic) lowering, same oracle
    candidates_scored: int
    peak_memory: float = 0.0          # per-device, of the chosen plan
    memory_budget: Optional[float] = None
    budget_pruned: int = 0
    budget_pruned_all: bool = False


def lower_costed(plan: ir.Plan, catalog: ir.Catalog, *,
                 profile: Optional[cost.DeviceProfile] = None,
                 backend: Optional[str] = None,
                 memory_budget: Optional[float] = None,
                 max_candidates: int = MAX_CANDIDATES,
                 ways: int = 1) -> Lowered:
    """Min-cost lowering. ``ways > 1`` opens per-node PartSpec sites
    (intra-query sharding over a ``ways``-device data mesh);
    ``memory_budget`` (defaulting to the profile's per-device budget)
    hard-rejects any candidate whose ``phys_peak_memory`` exceeds it —
    the serving tier's admission path for oversized single queries."""
    profile = profile or cost.default_profile()
    if memory_budget is None:
        memory_budget = profile.memory_budget
    graph = stage_graph.build(plan, catalog, backend=backend, profile=profile,
                              ways=ways)
    pruned = {"n": 0}

    def score(d: Dict[str, int]) -> float:
        """Oracle cost, or +inf for candidates the memory budget rejects.
        The hard gate already walked the peak, so plan_cost gets an
        explicitly unlimited budget instead of re-walking it (its paging
        penalty could never fire on a candidate that passed the gate)."""
        pp = graph.realize(d)
        if memory_budget is not None:
            if cost.phys_peak_memory(pp, catalog, profile) > memory_budget:
                pruned["n"] += 1
                return math.inf
        return cost.plan_cost(pp, catalog, profile, memory_budget=math.inf)

    default = dict(graph.default_decisions())
    best = default
    base_cost = score(default)
    best_cost = base_cost
    scored = 1
    open_sites = [s for s in graph.sites.values() if len(s.options) > 1]
    if open_sites:
        if graph.n_candidates() <= max_candidates:
            fixed = {sid: 0 for sid, s in graph.sites.items()
                     if len(s.options) == 1}
            for combo in itertools.product(
                    *(range(len(s.options)) for s in open_sites)):
                d = dict(fixed)
                d.update({s.sid: c for s, c in zip(open_sites, combo)})
                if d == best and scored > 0:
                    continue  # default already scored
                c = score(d)
                scored += 1
                if c < best_cost:  # strict: ties keep the tree order
                    best, best_cost = d, c
        else:
            # deterministic coordinate descent, two sweeps. Under a memory
            # budget the all-replicated default can be infeasible while no
            # single-site flip is (partitioning one node just moves the
            # full-size boundary), so the maximally partitioned vector is
            # scored as a second seed and the descent starts from the
            # better of the two.
            if graph.ways > 1:
                seed = graph.partitioned_decisions()
                c = score(seed)
                scored += 1
                if c < best_cost:
                    best, best_cost = seed, c
            for _ in range(2):
                moved = False
                for site in open_sites:
                    for oi in range(len(site.options)):
                        if oi == best[site.sid]:
                            continue
                        d = dict(best)
                        d[site.sid] = oi
                        c = score(d)
                        scored += 1
                        if c < best_cost:
                            best, best_cost = d, c
                            moved = True
                if not moved:
                    break
    pruned_all = math.isinf(best_cost) and pruned["n"] > 0
    if pruned_all:
        # every candidate busts the budget: fall back to tree order, but
        # say so — a silent fallback reads as "this plan fits" when the
        # real story is a misconfigured (or genuinely impossible) budget
        best = default
        best_cost = cost.plan_cost(graph.realize(best), catalog, profile,
                                   memory_budget=memory_budget)
        logger.warning(
            "memory budget %.3g B pruned all %d scored lowering candidates "
            "(ways=%d); falling back to tree order, which does NOT fit",
            memory_budget, scored, graph.ways)
    chosen = graph.realize(best)
    return Lowered(plan=chosen, decisions=best,
                   signature=graph.decision_signature(best),
                   cost=best_cost,
                   baseline_cost=(base_cost if not math.isinf(base_cost)
                                  else best_cost),
                   candidates_scored=scored,
                   peak_memory=cost.phys_peak_memory(chosen, catalog,
                                                     profile),
                   memory_budget=memory_budget,
                   budget_pruned=pruned["n"],
                   budget_pruned_all=pruned_all)


def choose_batch_realization(plan: ir.Plan, catalog: ir.Catalog,
                             batch_size: int, mesh=None,
                             profile: Optional[cost.DeviceProfile] = None
                             ) -> str:
    """'sharded' or 'batched' for one eligible micro-batch, by the shared
    oracle: a ``ways``-way sharded dispatch runs each shard on the
    ``batch_size/ways`` slice (weights replicated) but pays the profile's
    per-shard collective overhead. Each side is priced at the realization
    it would actually run — the sharded path lowers every node to the
    pure-XLA backend (``PLAN_LEVEL_BACKENDS``), so a pallas-annotated plan
    does not get pallas bandwidth credited to its sharded candidate.
    Ineligible meshes are always 'batched' (``core.mesh.can_shard`` is the
    legality gate, this is the cost gate)."""
    from repro.core import mesh as mesh_util
    from repro.core.lowering import lower

    if mesh is None or not mesh_util.can_shard(mesh, batch_size):
        return "batched"
    profile = profile or cost.default_profile()
    ways = mesh_util.batch_ways(mesh)
    pp_vmap = lower(plan, catalog, costed=False)
    pp_shard = lower(plan, catalog, costed=False, backend="sharded")
    c_vmap = cost.batched_plan_cost(pp_vmap, catalog, batch_size, profile)
    c_shard = cost.batched_plan_cost(pp_shard, catalog, batch_size, profile,
                                     ways=ways)
    return "sharded" if c_shard <= c_vmap else "batched"
