"""Optimizer strategies compared in the paper (Sec. V-B "baselines"):
Un-optimized / Arbitrary / Heuristic / Vanilla MCTS / Reusable MCTS.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, Optional, Tuple

from repro.core import ir
from repro.core.cost import DeviceProfile, default_profile, plan_cost
from repro.core.mcts import (ACTION_SPACE, VanillaMCTS, ReusableMCTS,
                             configure_action)
from repro.core.rules import ALL_RULES


def analytic_cost_fn(catalog: ir.Catalog, profile: DeviceProfile | None = None,
                     memory_budget: float | None = None) -> Callable:
    """The MCTS/greedy reward oracle: the same ``plan_cost`` entry point
    costed lowering scores its candidates with, against the same detected
    device profile — one notion of "cheap" across optimizer and executor."""
    profile = profile or default_profile()

    def cost(plan: ir.Plan) -> float:
        return plan_cost(plan, catalog, profile, memory_budget=memory_budget)

    return cost


def optimize_none(plan: ir.Plan, catalog: ir.Catalog, **kw) -> Tuple[ir.Plan, Dict]:
    return plan, {"strategy": "unoptimized"}


def optimize_arbitrary(plan: ir.Plan, catalog: ir.Catalog, max_apps: int = 30,
                       **kw) -> Tuple[ir.Plan, Dict]:
    """Paper: 'scans all co-optimization rules and applies all applicable
    rules' — no cost model, fixed scan order."""
    apps = 0
    for action in ACTION_SPACE:
        rule = ALL_RULES[action]
        for _ in range(4):
            cfgs = rule.configs(plan, catalog)
            if not cfgs or apps >= max_apps:
                break
            try:
                plan = rule.apply(plan, catalog, cfgs[0])
                apps += 1
            except Exception:
                break
    return plan, {"strategy": "arbitrary", "applications": apps}


def optimize_heuristic(plan: ir.Plan, catalog: ir.Catalog,
                       memory_budget: float = 512e6, **kw) -> Tuple[ir.Plan, Dict]:
    """Paper heuristic baseline: (1) aggressively push down filters/projects;
    (2) aggressively fuse ML operators; (3) tensor-relational transforms only
    for models larger than half the memory budget."""
    apps = 0
    # (1) pushdown + compaction to a fixpoint
    for _ in range(40):
        moved = False
        for action in ("R1-2", "R1-3", "compact"):
            rule = ALL_RULES[action]
            cfgs = rule.configs(plan, catalog)
            if cfgs:
                plan = rule.apply(plan, catalog, cfgs[0])
                apps += 1
                moved = True
                break
        if not moved:
            break
    # (2) fuse everything fusable
    rule = ALL_RULES["R4-1-fuse"]
    for _ in range(20):
        cfgs = rule.configs(plan, catalog)
        if not cfgs:
            break
        plan = rule.apply(plan, catalog, cfgs[0])
        apps += 1
    # (3) R3-1 for big tensors only
    rule = ALL_RULES["R3-1"]
    for _ in range(8):
        cfgs = [c for c in rule.configs(plan, catalog)
                if plan.registry.get(c.get("fn")).graph.nodes[c.get("idx")]
                .atom.param_bytes() > memory_budget / 2]
        if not cfgs:
            break
        plan = rule.apply(plan, catalog, cfgs[0])
        apps += 1
    return plan, {"strategy": "heuristic", "applications": apps}


def optimize_greedy(plan: ir.Plan, catalog: ir.Catalog,
                    cost_fn: Optional[Callable] = None, max_steps: int = 12,
                    **kw) -> Tuple[ir.Plan, Dict]:
    """Cost-model hill-climbing over configured actions (extra baseline)."""
    cost_fn = cost_fn or analytic_cost_fn(catalog)
    cur_cost = cost_fn(plan)
    for _ in range(max_steps):
        best, best_cost = None, cur_cost
        for action in ACTION_SPACE:
            res = configure_action(plan, catalog, action, cost_fn)
            if res is None:
                continue
            cand, _ = res
            c = cost_fn(cand)
            if c < best_cost:
                best, best_cost = cand, c
        if best is None:
            break
        plan, cur_cost = best, best_cost
    return plan, {"strategy": "greedy", "cost": cur_cost}


def optimize_vanilla_mcts(plan: ir.Plan, catalog: ir.Catalog,
                          cost_fn: Optional[Callable] = None,
                          iterations: int = 40, seed: int = 0,
                          **kw) -> Tuple[ir.Plan, Dict]:
    cost_fn = cost_fn or analytic_cost_fn(catalog)
    m = VanillaMCTS(catalog, cost_fn, iterations=iterations, seed=seed)
    out, stats = m.optimize(plan)
    stats["strategy"] = "vanilla_mcts"
    return out, stats


def timed(fn, plan, catalog, **kw):
    t0 = time.perf_counter()
    out, stats = fn(plan, catalog, **kw)
    stats["opt_seconds"] = time.perf_counter() - t0
    return out, stats


STRATEGIES = {
    "unoptimized": optimize_none,
    "arbitrary": optimize_arbitrary,
    "heuristic": optimize_heuristic,
    "greedy": optimize_greedy,
    "vanilla_mcts": optimize_vanilla_mcts,
}
