"""Pure-jnp oracle: tile-relational matmul == plain matmul."""
import jax.numpy as jnp


def block_matmul(x, w, n_tiles: int = 1):
    del n_tiles  # tiling is a physical detail; semantics are x @ w
    return (x.astype(jnp.float32) @ w.astype(jnp.float32)).astype(x.dtype)
