from repro.kernels.block_matmul import ops, ref  # noqa: F401
