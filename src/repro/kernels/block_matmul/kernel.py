"""Tiled matmul over a weight-tile relation — the R3-1 physical operator.

The paper stores W as a relation of column tiles and scans one tile at a time
through the buffer pool. On TPU the same blocking happens two levels down:
the weight is sharded over the `model` mesh axis (one shard's tiles per chip)
and this kernel streams (bk, bn) tiles HBM→VMEM, accumulating (bm, bn) output
blocks in VMEM scratch. Grid: (M/bm, Ntiles=N/bn, K/bk).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _block_matmul_kernel(x_ref, w_ref, o_ref, acc_ref, *, k_steps: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == k_steps - 1)
    def _finish():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def block_matmul_pallas(x: jax.Array, w: jax.Array, *, bm: int = 128,
                        bn: int = 128, bk: int = 512,
                        interpret: bool = True) -> jax.Array:
    m, k = x.shape
    k2, n = w.shape
    assert k == k2
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, "caller pads"
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        functools.partial(_block_matmul_kernel, k_steps=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w)
