"""Public wrapper for the R3-1 block_matmul kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels import common
from repro.kernels.block_matmul.kernel import block_matmul_pallas


@functools.partial(jax.jit, static_argnames=("n_tiles",))
def block_matmul(x: jax.Array, w: jax.Array, n_tiles: int = 8) -> jax.Array:
    m, k = x.shape
    n = w.shape[1]
    bm = 128 if m >= 128 else 8
    # tile width follows the relation's tile size, MXU-aligned
    bn = max(128, ((n // max(n_tiles, 1)) // 128) * 128) if n >= 128 else 128
    bk = 512 if k >= 512 else 128
    xp = common.pad_to(common.pad_to(x, 0, bm), 1, bk)
    wp = common.pad_to(common.pad_to(w, 0, bk), 1, bn)
    out = block_matmul_pallas(xp, wp, bm=bm, bn=bn, bk=bk,
                              interpret=common.use_interpret())
    return out[:m, :n]
