"""Shared kernel utilities."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def use_interpret() -> bool:
    """Pallas interpret mode everywhere except a real TPU backend."""
    return jax.default_backend() != "tpu"


def pad_to(x: jax.Array, axis: int, multiple: int, value=0.0) -> jax.Array:
    n = x.shape[axis]
    pad = (-n) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def cdiv(a: int, b: int) -> int:
    return -(-a // b)
