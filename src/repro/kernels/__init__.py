"""Pallas TPU kernels for the engine's compute hot spots.

Each kernel package has:
  kernel.py — pl.pallas_call + explicit BlockSpec VMEM tiling (TPU target)
  ops.py    — jit'd public wrapper (padding, backend/interpret selection)
  ref.py    — pure-jnp oracle used by the allclose test sweeps

On this CPU container kernels run with interpret=True; on TPU they compile
natively (block shapes are MXU-aligned multiples of 128).
"""
