"""Pure-jnp oracle for fused_dense."""
import jax
import jax.numpy as jnp


def fused_dense(x, w, b, act: str):
    y = x.astype(jnp.float32) @ w.astype(jnp.float32) + b.astype(jnp.float32)
    if act == "relu":
        y = jax.nn.relu(y)
    elif act == "sigmoid":
        y = jax.nn.sigmoid(y)
    elif act == "tanh":
        y = jnp.tanh(y)
    elif act == "gelu":
        y = jax.nn.gelu(y)
    elif act == "squared_relu":
        y = jnp.square(jax.nn.relu(y))
    elif act != "identity":
        raise ValueError(act)
    return y.astype(x.dtype)
