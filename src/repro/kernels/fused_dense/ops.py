"""Public jit'd wrapper for the fused_dense kernel (pads to block multiples)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import common
from repro.kernels.fused_dense.kernel import fused_dense_pallas


@functools.partial(jax.jit, static_argnames=("act",))
def fused_dense(x: jax.Array, w: jax.Array, b: jax.Array,
                act: str = "identity") -> jax.Array:
    m, k = x.shape
    n = w.shape[1]
    bm = 128 if m >= 128 else 8
    bn = 128 if n >= 128 else 128  # lane dim must be 128-aligned
    bk = 512 if k >= 512 else 128
    xp = common.pad_to(common.pad_to(x, 0, bm), 1, bk)
    wp = common.pad_to(common.pad_to(w, 0, bk), 1, bn)
    bp = common.pad_to(b, 0, bn)
    out = fused_dense_pallas(xp, wp, bp, act, bm=bm, bn=bn, bk=bk,
                             interpret=common.use_interpret())
    return out[:m, :n]
