"""Fused dense layer: act(x @ w + b) in one pass (paper R4-1's canonical
matMul→matAdd→activation fusion).

Tiling: grid (M/bm, N/bn, K/bk); A and B stream HBM→VMEM one (bm,bk)/(bk,bn)
block per step; a (bm,bn) f32 accumulator lives in VMEM scratch across the K
loop; bias-add + activation are applied on the final K step so the activated
output makes exactly one HBM round trip. Block shapes are MXU-aligned
(multiples of 128 on the matmul dims).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _apply_act(act: str, x):
    if act == "relu":
        return jnp.maximum(x, 0.0)
    if act == "sigmoid":
        return jax.nn.sigmoid(x)
    if act == "tanh":
        return jnp.tanh(x)
    if act == "gelu":
        return jax.nn.gelu(x)
    if act == "squared_relu":
        return jnp.square(jnp.maximum(x, 0.0))
    if act == "identity":
        return x
    raise ValueError(act)


def _fused_dense_kernel(x_ref, w_ref, b_ref, o_ref, acc_ref, *, act: str,
                        k_steps: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == k_steps - 1)
    def _finish():
        out = acc_ref[...] + b_ref[...].astype(jnp.float32)
        o_ref[...] = _apply_act(act, out).astype(o_ref.dtype)


def fused_dense_pallas(x: jax.Array, w: jax.Array, b: jax.Array, act: str,
                       *, bm: int = 128, bn: int = 128, bk: int = 512,
                       interpret: bool = True) -> jax.Array:
    m, k = x.shape
    k2, n = w.shape
    assert k == k2 and b.shape == (n,)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, "caller pads"
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        functools.partial(_fused_dense_kernel, act=act, k_steps=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w, b.reshape(1, n))
