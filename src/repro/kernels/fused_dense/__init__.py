from repro.kernels.fused_dense import ops, ref  # noqa: F401
