"""Pure-jnp oracle for forest inference (mean vote over complete trees)."""
import jax.numpy as jnp


def forest_predict(x, feat, thresh, leaf):
    n, _ = x.shape
    n_trees, n_nodes = feat.shape
    depth = (n_nodes + 1).bit_length() - 1
    node = jnp.zeros((n, n_trees), dtype=jnp.int32)
    t_idx = jnp.arange(n_trees)[None, :]
    for _ in range(depth):
        f = feat[t_idx, node]
        th = thresh[t_idx, node]
        xv = jnp.take_along_axis(x, f, axis=1)
        node = 2 * node + 1 + (xv > th).astype(jnp.int32)
    leaf_idx = node - n_nodes
    lv = leaf[t_idx, leaf_idx]
    return jnp.mean(lv, axis=1)
