"""Decision-forest inference kernel — the R3-2 physical operator.

TPU adaptation: tree traversal is branch- and gather-free. For a block of
rows and one tree:
  1. feature gather  x[feat[j]]  →  xv = x @ onehot(feat)ᵀ  (MXU matmul with
     a precomputed one-hot matrix, done once per tree, host-side in ops.py)
  2. decision bits   D = xv > thresh                (VPU compare, all nodes)
  3. traversal       node ← 2·node+1+D[node]; the D[node] gather is a
     one-hot select: sum((node == iota) · D)        (VPU, no gather op)
  4. leaf read       pred = onehot(leaf_idx) · leaf (VPU select)
Votes accumulate across the tree grid dimension in VMEM scratch.

Grid: (N/bm, T). Row block bm×d plus the per-tree one-hot (d×nodes) and
decision matrices (bm×nodes) bound the VMEM working set.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _forest_kernel(x_ref, fonehot_ref, thresh_ref, leaf_ref, o_ref, acc_ref,
                   *, depth: int, n_trees: int):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]                        # [bm, d]
    fo = fonehot_ref[0]                   # [d, nodes]
    th = thresh_ref[0]                    # [1, nodes] -> broadcast
    lv = leaf_ref[0]                      # [1, leaves]
    n_nodes = fo.shape[1]
    xv = jnp.dot(x, fo, preferred_element_type=jnp.float32)  # [bm, nodes]
    dec = (xv > th).astype(jnp.float32)   # [bm, nodes]
    bm = x.shape[0]
    node = jnp.zeros((bm,), jnp.int32)
    iota_nodes = jax.lax.broadcasted_iota(jnp.int32, (1, n_nodes), 1)
    for _ in range(depth):
        sel = (node[:, None] == iota_nodes).astype(jnp.float32)  # [bm, nodes]
        bit = jnp.sum(sel * dec, axis=1).astype(jnp.int32)
        node = 2 * node + 1 + bit
    leaf_idx = node - (n_nodes)           # complete tree: nodes = 2^depth - 1
    n_leaves = lv.shape[1]
    iota_leaves = jax.lax.broadcasted_iota(jnp.int32, (1, n_leaves), 1)
    lsel = (leaf_idx[:, None] == iota_leaves).astype(jnp.float32)
    pred = jnp.sum(lsel * lv, axis=1)     # [bm]
    acc_ref[...] += pred[:, None]

    @pl.when(t == n_trees - 1)
    def _finish():
        o_ref[...] = (acc_ref[...] / n_trees).astype(o_ref.dtype)


def forest_pallas(x: jax.Array, fonehot: jax.Array, thresh: jax.Array,
                  leaf: jax.Array, depth: int, *, bm: int = 128,
                  interpret: bool = True) -> jax.Array:
    n, d = x.shape
    n_trees, _, n_nodes = fonehot.shape
    assert n % bm == 0, "caller pads"
    grid = (n // bm, n_trees)
    out = pl.pallas_call(
        functools.partial(_forest_kernel, depth=depth, n_trees=n_trees),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, d), lambda i, t: (i, 0)),
            pl.BlockSpec((1, d, n_nodes), lambda i, t: (t, 0, 0)),
            pl.BlockSpec((1, 1, n_nodes), lambda i, t: (t, 0, 0)),
            pl.BlockSpec((1, 1, leaf.shape[2]), lambda i, t: (t, 0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, 1), lambda i, t: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 1), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, 1), jnp.float32)],
        interpret=interpret,
    )(x, fonehot, thresh, leaf)
    return out[:, 0]
