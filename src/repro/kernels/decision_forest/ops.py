"""Public wrapper: converts node arrays to one-hot feature selectors (host
side, once per model) and pads row blocks."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import common
from repro.kernels.decision_forest.kernel import forest_pallas


@jax.jit
def forest_predict(x: jax.Array, feat: jax.Array, thresh: jax.Array,
                   leaf: jax.Array) -> jax.Array:
    n, d = x.shape
    n_trees, n_nodes = feat.shape
    depth = (n_nodes + 1).bit_length() - 1
    fonehot = jax.nn.one_hot(feat, d, axis=1, dtype=jnp.float32)  # [T, d, nodes]
    bm = 128 if n >= 128 else 8
    xp = common.pad_to(x.astype(jnp.float32), 0, bm)
    out = forest_pallas(xp, fonehot, thresh.reshape(n_trees, 1, n_nodes),
                        leaf.reshape(n_trees, 1, -1), depth, bm=bm,
                        interpret=common.use_interpret())
    return out[:n].astype(x.dtype)
