from repro.kernels.decision_forest import ops, ref  # noqa: F401
