"""Naive attention oracle."""
import jax.numpy as jnp


def attention(q, k, v, causal: bool = True, scale: float | None = None):
    """q,k,v: [BH, S, D] (kv may have different S)."""
    d = q.shape[-1]
    scale = scale if scale is not None else d ** -0.5
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        sq, sk = s.shape[1], s.shape[2]
        mask = jnp.tril(jnp.ones((sq, sk), bool), k=sk - sq)
        s = jnp.where(mask, s, -1e30)
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)
