"""Flash attention (prefill) — tiled online-softmax attention.

Grid: (B·H, Sq/bq, Skv/bkv), kv innermost. Scratch: f32 accumulator
(bq, D) + running max/denominator (bq, 128 lanes, value broadcast) persist
across the kv loop for a fixed q block; output is normalized and written on
the final kv step. Causal masking uses global row/col indices; fully-masked
blocks contribute exp(-inf)=0 (block-skip is a TPU scheduling refinement,
see EXPERIMENTS §Perf).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, bq: int, bkv: int,
                  kv_steps: int, kv_len: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)            # [bq, D]
    k = k_ref[0].astype(jnp.float32)            # [bkv, D]
    v = v_ref[0].astype(jnp.float32)            # [bkv, D]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    cols = ki * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
    mask = cols < kv_len  # key padding
    if causal:
        rows = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
        mask = mask & (rows >= cols)
    s = jnp.where(mask, s, _NEG)
    m_prev = m_ref[:, :1]                       # [bq, 1]
    l_prev = l_ref[:, :1]
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_prev + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(ki == kv_steps - 1)
    def _finish():
        denom = jnp.maximum(l_ref[:, :1], 1e-30)
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool, scale: float, kv_len: int,
                           bq: int = 128, bkv: int = 128,
                           interpret: bool = True) -> jax.Array:
    bh, sq, d = q.shape
    _, skv, _ = k.shape
    assert sq % bq == 0 and skv % bkv == 0, "caller pads"
    grid = (bh, sq // bq, skv // bkv)
    return pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, causal=causal, bq=bq,
                          bkv=bkv, kv_steps=grid[2], kv_len=kv_len),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bkv, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bkv, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, 128), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
