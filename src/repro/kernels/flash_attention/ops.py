"""Public wrapper for flash attention: [B, H, S, D] API, GQA via KV repeat
at the head-group level, padding to block multiples."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import common
from repro.kernels.flash_attention.kernel import flash_attention_pallas


@functools.partial(jax.jit, static_argnames=("causal",))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True) -> jax.Array:
    """q: [B, Hq, S, D]; k,v: [B, Hkv, S, D] with Hq % Hkv == 0."""
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    if hkv != hq:
        rep = hq // hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    bq = 128 if s >= 128 else 8
    bkv = 128 if s >= 128 else 8
    qf = common.pad_to(q.reshape(b * hq, s, d), 1, bq)
    kf = common.pad_to(k.reshape(b * hq, s, d), 1, bkv)
    vf = common.pad_to(v.reshape(b * hq, s, d), 1, bkv)
    out = flash_attention_pallas(qf, kf, vf, causal=causal, scale=d ** -0.5,
                                 kv_len=s, bq=bq, bkv=bkv,
                                 interpret=common.use_interpret())
    return out[:, :s].reshape(b, hq, s, d)
