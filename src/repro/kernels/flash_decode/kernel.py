"""Flash decode — one-token attention over a (possibly sharded) KV block.

This is the O3 insight (partition the big operand, compute per block,
aggregate) applied to the KV cache: each `model`-axis shard holds an S-slice
of the cache, runs this kernel over its local slice, and the partial
(acc, m, l) triples are merged across shards with a log-sum-exp psum
(models/attention.py). GQA handled natively: the q block is the G=Hq/Hkv
query group attending to one kv head.

Grid: (B·Hkv, S/bs). Scratch: f32 acc (G, D) + running m/l (G, 128).
Outputs: unnormalized acc [BHkv, G, D], m and l broadcast on lanes
[BHkv, G, 128].
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG = -1e30


def _decode_kernel(q_ref, k_ref, v_ref, acc_out, m_out, l_out,
                   acc_ref, m_ref, l_ref, *, scale: float, s_steps: int,
                   bs: int, kv_len: int):
    si = pl.program_id(1)

    @pl.when(si == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)          # [G, D]
    k = k_ref[0].astype(jnp.float32)          # [bs, D]
    v = v_ref[0].astype(jnp.float32)          # [bs, D]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    cols = si * bs + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(cols < kv_len, s, _NEG)
    m_prev = m_ref[:, :1]
    l_prev = l_ref[:, :1]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_prev + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(si == s_steps - 1)
    def _finish():
        acc_out[0] = acc_ref[...].astype(acc_out.dtype)
        m_out[0] = m_ref[...].astype(m_out.dtype)
        l_out[0] = l_ref[...].astype(l_out.dtype)


def flash_decode_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        scale: float, kv_len: int, bs: int = 512,
                        interpret: bool = True):
    """q: [BHkv, G, D]; k, v: [BHkv, S, D]. Returns (acc, m, l) partials."""
    bh, g, d = q.shape
    _, s, _ = k.shape
    assert s % bs == 0, "caller pads"
    grid = (bh, s // bs)
    return pl.pallas_call(
        functools.partial(_decode_kernel, scale=scale, s_steps=grid[1],
                          bs=bs, kv_len=kv_len),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, g, d), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, bs, d), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, bs, d), lambda b, j: (b, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, g, d), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, g, 128), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, g, 128), lambda b, j: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, g, d), jnp.float32),
            jax.ShapeDtypeStruct((bh, g, 128), jnp.float32),
            jax.ShapeDtypeStruct((bh, g, 128), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((g, d), jnp.float32),
            pltpu.VMEM((g, 128), jnp.float32),
            pltpu.VMEM((g, 128), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
