"""Oracle for flash decode: full-softmax one-token attention + the partial
(acc, m, l) form used for cross-shard merging."""
import jax.numpy as jnp


def decode_attention(q, k, v, scale=None):
    """q: [BH, G, D]; k,v: [BH, S, D] -> [BH, G, D]."""
    d = q.shape[-1]
    scale = scale if scale is not None else d ** -0.5
    s = jnp.einsum("bgd,bsd->bgs", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    return jnp.einsum("bgs,bsd->bgd", p, v.astype(jnp.float32))


def decode_partials(q, k, v, scale=None):
    """Reference (acc, m, l) partials over the full local block."""
    d = q.shape[-1]
    scale = scale if scale is not None else d ** -0.5
    s = jnp.einsum("bgd,bsd->bgs", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    m = s.max(axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = p.sum(axis=-1, keepdims=True)
    acc = jnp.einsum("bgs,bsd->bgd", p, v.astype(jnp.float32))
    return acc, m[..., 0], l[..., 0]


def merge_partials(accs, ms, ls):
    """Merge per-shard partials (lists) into the exact softmax output."""
    m_all = jnp.max(jnp.stack(ms), axis=0)
    num = 0.0
    den = 0.0
    for acc, m, l in zip(accs, ms, ls):
        w = jnp.exp(m - m_all)
        num = num + acc * w[..., None]
        den = den + l * w
    return num / den[..., None]
