"""Public wrapper for flash decode."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import common
from repro.kernels.flash_decode.kernel import flash_decode_pallas


@jax.jit
def decode_partials(q: jax.Array, k: jax.Array, v: jax.Array):
    """q: [BH, G, D]; k,v: [BH, S, D]. Returns (acc [BH,G,D], m [BH,G],
    l [BH,G]) — unnormalized partials for cross-shard lse merging."""
    bh, g, d = q.shape
    s = k.shape[1]
    bs = 512 if s >= 512 else (128 if s >= 128 else 8)
    kp = common.pad_to(k, 1, bs)
    vp = common.pad_to(v, 1, bs)
    gp = 8 if g < 8 else g
    qp = common.pad_to(q, 1, gp) if g < 8 else q
    acc, m, l = flash_decode_pallas(qp, kp, vp, scale=d ** -0.5, kv_len=s,
                                    bs=bs, interpret=common.use_interpret())
    return acc[:, :g], m[:, :g, 0], l[:, :g, 0]


@jax.jit
def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    """Single-shard convenience: normalized one-token attention."""
    acc, m, l = decode_partials(q, k, v)
    return acc / jnp.maximum(l, 1e-30)[..., None]
