from repro.kernels.flash_decode import ops, ref  # noqa: F401
