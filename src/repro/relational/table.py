"""Static-shape columnar Table.

A Table is a pytree: ``columns`` maps name -> jnp array whose leading axis is
the row capacity; ``valid`` is a bool[capacity] mask. Invalid rows carry
garbage values and must never influence query results — every operator and
every test is mask-aware.

Columns may be scalar (shape [N]) or vector (shape [N, d]) — vector columns
are the paper's ``V: vec in R^d`` feature-vector columns (Sec. III-A).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Mapping

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Table:
    columns: Dict[str, jax.Array]
    valid: jax.Array  # bool[capacity]

    # -- pytree protocol -------------------------------------------------
    def tree_flatten(self):
        names = tuple(sorted(self.columns))
        children = tuple(self.columns[n] for n in names) + (self.valid,)
        return children, names

    @classmethod
    def tree_unflatten(cls, names, children):
        return cls(columns=dict(zip(names, children[:-1])), valid=children[-1])

    # -- construction ----------------------------------------------------
    @classmethod
    def from_columns(cls, columns: Mapping[str, jax.Array], valid=None) -> "Table":
        cols = {k: jnp.asarray(v) for k, v in columns.items()}
        n = next(iter(cols.values())).shape[0]
        for k, v in cols.items():
            if v.shape[0] != n:
                raise ValueError(f"column {k} has {v.shape[0]} rows, expected {n}")
        if valid is None:
            valid = jnp.ones((n,), dtype=bool)
        return cls(columns=cols, valid=jnp.asarray(valid, dtype=bool))

    @classmethod
    def empty_like(cls, other: "Table", capacity: int) -> "Table":
        cols = {
            k: jnp.zeros((capacity,) + v.shape[1:], v.dtype)
            for k, v in other.columns.items()
        }
        return cls(columns=cols, valid=jnp.zeros((capacity,), dtype=bool))

    # -- accessors --------------------------------------------------------
    @property
    def capacity(self) -> int:
        return int(self.valid.shape[0])

    @property
    def names(self):
        return tuple(sorted(self.columns))

    def __getitem__(self, name: str) -> jax.Array:
        return self.columns[name]

    def num_valid(self) -> jax.Array:
        return jnp.sum(self.valid.astype(jnp.int32))

    def with_columns(self, new: Mapping[str, jax.Array]) -> "Table":
        cols = dict(self.columns)
        cols.update(new)
        return Table(columns=cols, valid=self.valid)

    def select(self, names) -> "Table":
        return Table(columns={n: self.columns[n] for n in names}, valid=self.valid)

    def rename(self, mapping: Mapping[str, str]) -> "Table":
        cols = {mapping.get(k, k): v for k, v in self.columns.items()}
        return Table(columns=cols, valid=self.valid)

    # -- materialization (host side, for tests / oracles) -----------------
    def to_numpy(self) -> Dict[str, np.ndarray]:
        """Valid rows only, as numpy, in storage order."""
        mask = np.asarray(self.valid)
        return {k: np.asarray(v)[mask] for k, v in self.columns.items()}

    def canonical(self) -> Dict[str, np.ndarray]:
        """Valid rows sorted by a total order over all scalar columns — used
        to compare plan outputs irrespective of row order."""
        data = self.to_numpy()
        if not data:
            return data
        n = next(iter(data.values())).shape[0]
        if n == 0:
            return data
        keys = []
        for name in sorted(data):
            arr = data[name]
            if arr.ndim == 1:
                keys.append(np.round(arr.astype(np.float64), 4))
            else:
                keys.append(np.round(arr.astype(np.float64).sum(axis=tuple(range(1, arr.ndim))), 4))
        order = np.lexsort(tuple(reversed(keys)))
        return {k: v[order] for k, v in data.items()}
