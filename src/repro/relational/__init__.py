"""Columnar relational engine in pure JAX.

Tables are struct-of-arrays with a static row capacity and a validity mask
(XLA requires static shapes). All relational operators are pure functions
Table -> Table and fully jit/vmap/shard_map compatible.
"""
from repro.relational.table import Table
from repro.relational import ops

__all__ = ["Table", "ops"]
