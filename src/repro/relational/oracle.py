"""Pure-numpy reference semantics for the relational operators.

Tables here are plain dicts of numpy arrays containing only live rows; used
by tests and by the equivalence checker to validate the JAX engine and every
rewrite rule.
"""
from __future__ import annotations

from typing import Dict, Mapping, Tuple

import numpy as np

NpTable = Dict[str, np.ndarray]


def filter_(t: NpTable, mask: np.ndarray) -> NpTable:
    return {k: v[mask] for k, v in t.items()}


def project(t: NpTable, new_columns: Mapping[str, np.ndarray], keep=None) -> NpTable:
    out = dict(t) if keep is None else {k: t[k] for k in keep}
    out.update(new_columns)
    return out


def fk_join(left: NpTable, right: NpTable, left_key: str, right_key: str,
            rprefix: str = "") -> NpTable:
    rk = right[right_key]
    lk = left[left_key]
    idx_map = {int(k): i for i, k in enumerate(rk)}
    matches = np.array([idx_map.get(int(k), -1) for k in lk])
    keep = matches >= 0
    src = matches[keep]
    out = {k: v[keep] for k, v in left.items()}
    for name, col in right.items():
        out_name = rprefix + name
        if out_name == left_key and name == right_key:
            continue
        out[out_name] = col[src]
    return out


def cross_join(a: NpTable, b: NpTable, aprefix: str = "", bprefix: str = "") -> NpTable:
    na = len(next(iter(a.values()))) if a else 0
    nb = len(next(iter(b.values()))) if b else 0
    out = {}
    for name, col in a.items():
        out[aprefix + name] = np.repeat(col, nb, axis=0)
    for name, col in b.items():
        reps = (na,) + (1,) * (col.ndim - 1)
        out[bprefix + name] = np.tile(col, reps)
    return out


def aggregate(t: NpTable, key: str, aggs: Mapping[str, Tuple[str, str]]) -> NpTable:
    keys = t[key]
    uniq = np.unique(keys)
    out: NpTable = {key: uniq.astype(np.int32)}
    for out_name, (kind, in_col) in aggs.items():
        vals = []
        for u in uniq:
            sel = keys == u
            if kind == "count":
                vals.append(float(sel.sum()))
            else:
                x = t[in_col][sel].astype(np.float64)
                vals.append({"sum": x.sum(axis=0), "mean": x.mean(axis=0),
                             "min": x.min(axis=0), "max": x.max(axis=0)}[kind])
        out[out_name] = np.array(vals, dtype=np.float32)
    return out


def union_all(a: NpTable, b: NpTable) -> NpTable:
    return {k: np.concatenate([a[k], b[k]], axis=0) for k in a}


def canonical(t: NpTable) -> NpTable:
    if not t:
        return t
    n = len(next(iter(t.values())))
    if n == 0:
        return t
    keys = []
    for name in sorted(t):
        arr = t[name]
        if arr.ndim == 1:
            keys.append(np.round(arr.astype(np.float64), 4))
        else:
            keys.append(np.round(arr.astype(np.float64).sum(axis=tuple(range(1, arr.ndim))), 4))
    order = np.lexsort(tuple(reversed(keys)))
    return {k: v[order] for k, v in t.items()}
