"""Relational operators over static-shape columnar Tables.

Semantics (mask-aware):
  - ``filter``     : valid &= predicate(valid rows); never changes capacity.
  - ``compact``    : physically gathers valid rows to the front of a (usually
                     smaller) static capacity. This is how filter/project
                     pushdown pays off on TPU: downstream per-row ML compute
                     is proportional to *capacity*, not to live rows.
  - ``project``    : adds/overwrites columns (row-aligned compute).
  - ``fk_join``    : inner equi-join where the right side's key is unique
                     (dimension table). Output capacity == left capacity.
  - ``cross_join`` : cartesian product, capacity Na*Nb.
  - ``aggregate``  : group-by over one key column with sum/mean/count/min/max,
                     output capacity = static group bound.
  - ``union_all``  : concatenation.

All functions are jit-compatible and differentiable where meaningful.
"""
from __future__ import annotations

from typing import Callable, Dict, Mapping, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.relational.table import Table

_INT_SENTINEL = jnp.iinfo(jnp.int32).max


# ---------------------------------------------------------------------------
# filter / compact / project
# ---------------------------------------------------------------------------

def filter_(t: Table, mask: jax.Array) -> Table:
    """Keep rows where ``mask`` holds. ``mask`` is bool[capacity]."""
    return Table(columns=t.columns, valid=t.valid & mask)


def compact(t: Table, capacity: int) -> Table:
    """Gather valid rows to the front of a new static ``capacity``.

    If there are more valid rows than ``capacity`` the extra rows are dropped
    (the optimizer only compacts when its selectivity bound says this cannot
    happen; tests exercise the bound).
    """
    n = t.capacity
    # stable order: valid rows first, preserving relative order.
    order = jnp.argsort(jnp.where(t.valid, 0, 1), stable=True)
    take = order[:capacity] if capacity <= n else jnp.pad(order, (0, capacity - n))
    cols = {k: v[take] for k, v in t.columns.items()}
    rank = jnp.arange(capacity)
    nvalid = t.num_valid()
    valid = rank < jnp.minimum(nvalid, capacity)
    if capacity > n:
        valid = valid & (rank < n)
    return Table(columns=cols, valid=valid)


def project(t: Table, new_columns: Mapping[str, jax.Array], keep: Sequence[str] | None = None) -> Table:
    """Add/overwrite columns; optionally restrict the kept input columns."""
    base = t if keep is None else t.select(keep)
    return base.with_columns(dict(new_columns))


# ---------------------------------------------------------------------------
# joins
# ---------------------------------------------------------------------------

def fk_join(left: Table, right: Table, left_key: str, right_key: str,
            rprefix: str = "") -> Table:
    """Inner FK equi-join: every left row matches <=1 valid right row.

    Right keys are assumed unique among valid rows (dimension table). Output
    rows align with left rows; unmatched left rows become invalid.
    """
    lk = jnp.asarray(left[left_key], jnp.int32)
    rk = jnp.asarray(right[right_key], jnp.int32)
    rk_m = jnp.where(right.valid, rk, _INT_SENTINEL)
    order = jnp.argsort(rk_m)
    sorted_keys = rk_m[order]
    pos = jnp.searchsorted(sorted_keys, lk)
    pos_c = jnp.clip(pos, 0, rk.shape[0] - 1)
    matched = (sorted_keys[pos_c] == lk) & (lk != _INT_SENTINEL)
    src = order[pos_c]
    cols = dict(left.columns)
    for name, col in right.columns.items():
        out_name = rprefix + name
        if out_name == left_key and name == right_key:
            continue  # join key identical; keep left copy
        cols[out_name] = col[src]
    valid = left.valid & matched & right.valid[src]
    return Table(columns=cols, valid=valid)


def cross_join(a: Table, b: Table, aprefix: str = "", bprefix: str = "") -> Table:
    """Cartesian product. Row (ia, ib) lands at index ia * Nb + ib."""
    na, nb = a.capacity, b.capacity
    cols: Dict[str, jax.Array] = {}
    for name, col in a.columns.items():
        cols[aprefix + name] = jnp.repeat(col, nb, axis=0, total_repeat_length=na * nb)
    for name, col in b.columns.items():
        cols[bprefix + name] = jnp.tile(col, (na,) + (1,) * (col.ndim - 1))
    valid = jnp.repeat(a.valid, nb, total_repeat_length=na * nb) & jnp.tile(b.valid, (na,))
    return Table(columns=cols, valid=valid)


# ---------------------------------------------------------------------------
# aggregate
# ---------------------------------------------------------------------------

_AGG_KINDS = ("sum", "mean", "count", "min", "max")


def _dense_group_ids(keys: jax.Array, valid: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Map arbitrary int32 keys (valid rows) to dense ids [0..G).

    Returns (gid[N] with invalid rows mapped to a padding id, rep_key[N]
    giving the key value for each dense id slot, num_groups scalar).
    """
    n = keys.shape[0]
    km = jnp.where(valid, keys.astype(jnp.int32), _INT_SENTINEL)
    order = jnp.argsort(km)
    s = km[order]
    newseg = jnp.concatenate([jnp.array([True]), s[1:] != s[:-1]])
    newseg = newseg & (s != _INT_SENTINEL)
    gid_sorted = jnp.cumsum(newseg.astype(jnp.int32)) - 1
    gid_sorted = jnp.where(s == _INT_SENTINEL, n, gid_sorted)  # pad bucket
    inv = jnp.argsort(order)
    gid = gid_sorted[inv]
    num_groups = jnp.sum(newseg.astype(jnp.int32))
    # representative key per dense id (first occurrence in sorted order)
    rep = jnp.full((n,), _INT_SENTINEL, jnp.int32)
    rep = rep.at[jnp.where(newseg, gid_sorted, n)].set(s, mode="drop")
    return gid, rep, num_groups


def aggregate(t: Table, key: str, aggs: Mapping[str, Tuple[str, str]],
              num_groups: int) -> Table:
    """Group by ``key``; ``aggs`` maps out_name -> (kind, in_column).

    kind in {sum, mean, count, min, max}. Output capacity = ``num_groups``
    (static upper bound on distinct keys; rows beyond the bound are dropped).
    The group key is emitted under its original name.
    """
    gid, rep, ng = _dense_group_ids(t[key], t.valid)
    if rep.shape[0] < num_groups:  # more group slots than input rows
        rep = jnp.pad(rep, (0, num_groups - rep.shape[0]),
                      constant_values=_INT_SENTINEL)
    seg = jnp.where(gid < num_groups, gid, num_groups)  # overflow+padding bucket
    ones = t.valid.astype(jnp.float32)
    counts = jax.ops.segment_sum(ones, seg, num_segments=num_groups + 1)[:num_groups]
    cols: Dict[str, jax.Array] = {key: rep[:num_groups]}
    for out_name, (kind, in_col) in aggs.items():
        if kind not in _AGG_KINDS:
            raise ValueError(f"unknown agg kind {kind}")
        if kind == "count":
            cols[out_name] = counts
            continue
        x = t[in_col].astype(jnp.float32)
        mask = t.valid
        if x.ndim > 1:
            mask = mask.reshape((-1,) + (1,) * (x.ndim - 1))
        if kind in ("sum", "mean"):
            xm = jnp.where(mask, x, 0.0)
            s = jax.ops.segment_sum(xm, seg, num_segments=num_groups + 1)[:num_groups]
            if kind == "mean":
                denom = jnp.maximum(counts, 1.0)
                denom = denom.reshape((-1,) + (1,) * (x.ndim - 1)) if x.ndim > 1 else denom
                s = s / denom
            cols[out_name] = s
        elif kind == "min":
            xm = jnp.where(mask, x, jnp.inf)
            cols[out_name] = jax.ops.segment_min(xm, seg, num_segments=num_groups + 1)[:num_groups]
        else:  # max
            xm = jnp.where(mask, x, -jnp.inf)
            cols[out_name] = jax.ops.segment_max(xm, seg, num_segments=num_groups + 1)[:num_groups]
    valid = jnp.arange(num_groups) < jnp.minimum(ng, num_groups)
    return Table(columns=cols, valid=valid)


# ---------------------------------------------------------------------------
# set ops
# ---------------------------------------------------------------------------

def union_all(a: Table, b: Table) -> Table:
    if set(a.columns) != set(b.columns):
        raise ValueError("union_all requires identical schemas")
    cols = {k: jnp.concatenate([a.columns[k], b.columns[k]], axis=0) for k in a.columns}
    return Table(columns=cols, valid=jnp.concatenate([a.valid, b.valid]))
