"""Gradient compression: int8 quantized all-reduce with error feedback.

For cross-pod data parallelism the gradient all-reduce crosses the slow
inter-pod links; 4x compression (f32 -> int8 + per-tensor scale) with an
error-feedback accumulator preserves convergence (1-bit Adam / EF-SGD
lineage). Used by the train loop when ``compress_grads=True``; unit-tested
for bounded error and error-feedback exactness over repeated steps.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def quantize(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def _unzip3(tree_fn, a, b):
    la, treedef = jax.tree.flatten(a)
    lb = jax.tree.leaves(b)
    xs, ys, zs = [], [], []
    for ga, gb in zip(la, lb):
        x, y, z = tree_fn(ga, gb)
        xs.append(x)
        ys.append(y)
        zs.append(z)
    un = jax.tree.unflatten
    return un(treedef, xs), un(treedef, ys), un(treedef, zs)


def compress_tree(grads: Any, error: Any):
    """Quantize a gradient pytree with error feedback.

    Returns ((q_tree, scale_tree), new_error_tree)."""

    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q, s = quantize(g32)
        return q, s, g32 - dequantize(q, s)

    qs, ss, es = _unzip3(one, grads, error)
    return (qs, ss), es


def decompress_tree(q_and_scale) -> Any:
    qs, ss = q_and_scale
    return jax.tree.map(dequantize, qs, ss)


def compressed_psum(grads: Any, error: Any, axis_name: str):
    """Quantize -> psum(int32) -> dequantize, with error-feedback state.

    shard_map-compatible: the wire format is int8 widened to int32 for the
    accumulation (safe for <= 2^23 replicas)."""

    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q, s = quantize(g32)
        total = jax.lax.psum(q.astype(jnp.int32), axis_name)
        s_max = jax.lax.pmax(s, axis_name)
        n = jax.lax.psum(1, axis_name)
        mean = total.astype(jnp.float32) * s_max / n
        return mean, g32 - dequantize(q, s), None

    summed, new_err, _ = _unzip3(one, grads, error)
    return summed, new_err


def init_error(grads_template: Any) -> Any:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32),
                        grads_template)
