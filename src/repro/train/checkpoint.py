"""Fault-tolerant checkpointing.

Writes one .npz per (host) shard plus a JSON manifest carrying step, config
hash, mesh descriptor and tree structure. Restore validates the manifest,
re-shards onto the (possibly different) current mesh, and resumes. Atomic
via write-to-tmp + rename so a preemption mid-save never corrupts the latest
checkpoint; retention keeps the last K steps.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import tempfile
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree) -> Tuple[Dict[str, np.ndarray], Dict[str, str]]:
    flat, dtypes = {}, {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        arr = np.asarray(leaf)
        dtypes[key] = str(arr.dtype)
        if arr.dtype == jnp.bfloat16:
            arr = arr.view(np.uint16)  # npz has no native bf16
        flat[key] = arr
    return flat, dtypes


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def config_hash(cfg) -> str:
    return hashlib.sha1(repr(cfg).encode()).hexdigest()[:16]


def save(ckpt_dir: str, step: int, state: Any, cfg=None,
         mesh_descr: str = "", keep: int = 3) -> str:
    """Atomic checkpoint save. Returns the checkpoint path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_")
    try:
        flat, dtypes = _flatten(state)
        np.savez(os.path.join(tmp, "shard_0.npz"), **flat)
        manifest = {
            "step": step,
            "config_hash": config_hash(cfg) if cfg is not None else None,
            "mesh": mesh_descr,
            "keys": sorted(flat.keys()),
            "shapes": {k: list(v.shape) for k, v in flat.items()},
            "dtypes": dtypes,
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except Exception:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    _retain(ckpt_dir, keep)
    return final


def _retain(ckpt_dir: str, keep: int):
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and
             os.path.exists(os.path.join(ckpt_dir, d, "manifest.json"))]
    return max(steps) if steps else None


def restore(ckpt_dir: str, template: Any, step: Optional[int] = None,
            cfg=None, shardings=None) -> Tuple[Any, int]:
    """Restore into the structure of ``template``; validates config hash;
    re-shards with ``shardings`` (pytree of NamedSharding) when given —
    this is the elastic-rescale path."""
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    if cfg is not None and manifest["config_hash"] not in (None,
                                                           config_hash(cfg)):
        raise ValueError("checkpoint config hash mismatch — refusing to load "
                         f"({manifest['config_hash']} != {config_hash(cfg)})")
    data = np.load(os.path.join(path, "shard_0.npz"))
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for p, leaf in paths:
        key = "/".join(_path_str(x) for x in p)
        arr = data[key]
        if manifest["dtypes"].get(key) == "bfloat16":
            import ml_dtypes
            arr = arr.view(ml_dtypes.bfloat16)
        if not hasattr(leaf, "shape"):  # python scalar leaf (e.g. pipe state)
            leaves.append(type(leaf)(arr.item()))
            continue
        if list(arr.shape) != list(leaf.shape):
            raise ValueError(f"{key}: shape {arr.shape} != {leaf.shape}")
        leaves.append(jnp.asarray(arr, dtype=leaf.dtype))
    state = jax.tree_util.tree_unflatten(treedef, [l for l in leaves])
    if shardings is not None:
        state = jax.device_put(state, shardings)
    return state, step
