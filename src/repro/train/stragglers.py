"""Straggler mitigation + preemption handling.

On a real multi-pod fleet, slow hosts show up as step-time outliers. The
watchdog keeps an EWMA of step latency; a step exceeding ``threshold`` x the
EWMA marks its host suspect, and after ``strikes`` consecutive marks the
policy fires: for input stragglers, redistribute the suspect's shards to
backups (``reassignment``); for compute stragglers the caller triggers an
elastic re-mesh that drops the host (train/elastic.py). A PreemptionGuard
turns SIGTERM into a checkpoint-then-exit. The decision logic is pure and
unit-tested; the signal path is exercised in tests via direct invocation.
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Callable, Dict, List, Optional


@dataclasses.dataclass
class StepTimer:
    ewma: float = 0.0
    beta: float = 0.9
    n: int = 0

    def update(self, dt: float) -> float:
        self.n += 1
        if self.n == 1:
            self.ewma = dt
        else:
            self.ewma = self.beta * self.ewma + (1 - self.beta) * dt
        return self.ewma


@dataclasses.dataclass
class StragglerWatchdog:
    n_hosts: int
    threshold: float = 2.0
    strikes_to_act: int = 3
    timer: StepTimer = dataclasses.field(default_factory=StepTimer)
    strikes: Dict[int, int] = dataclasses.field(default_factory=dict)
    evicted: List[int] = dataclasses.field(default_factory=list)

    def observe(self, host_times: Dict[int, float]) -> List[int]:
        """Feed per-host step times; returns hosts to evict this round."""
        mean = sum(host_times.values()) / max(len(host_times), 1)
        self.timer.update(mean)
        to_evict = []
        for h, t in host_times.items():
            if h in self.evicted:
                continue
            if self.timer.ewma > 0 and t > self.threshold * self.timer.ewma:
                self.strikes[h] = self.strikes.get(h, 0) + 1
            else:
                self.strikes[h] = 0
            if self.strikes.get(h, 0) >= self.strikes_to_act:
                to_evict.append(h)
        for h in to_evict:
            self.evicted.append(h)
        return to_evict

    def reassignment(self, shards_per_host: Dict[int, List[int]]
                     ) -> Dict[int, List[int]]:
        """Redistribute evicted hosts' data shards round-robin to survivors."""
        survivors = [h for h in shards_per_host if h not in self.evicted]
        if not survivors:
            raise RuntimeError("all hosts evicted")
        out = {h: list(s) for h, s in shards_per_host.items()
               if h not in self.evicted}
        orphan = [s for h in self.evicted
                  for s in shards_per_host.get(h, [])]
        for i, s in enumerate(orphan):
            out[survivors[i % len(survivors)]].append(s)
        return out


class PreemptionGuard:
    """SIGTERM -> set flag; train loop checkpoints and exits cleanly."""

    def __init__(self, install: bool = True):
        self.preempted = False
        if install:
            try:
                signal.signal(signal.SIGTERM, self._handler)
            except ValueError:
                pass  # not on main thread (tests)

    def _handler(self, signum, frame):
        self.preempted = True

    def trigger(self):  # for tests
        self.preempted = True
