"""Elastic scaling: rebuild the mesh from the surviving device count and
re-shard training state.

Policy: the model axis is preserved (its degree is baked into the layer
shardings and kernel block shapes); the data-parallel degree shrinks/grows to
``devices // model_parallel``. Any devices beyond data*model are left idle
(reported). State moves via jax.device_put with the new NamedShardings —
on a real fleet this is the resharding all-gather/scatter; the checkpoint
path (restore with new shardings) covers the full-restart case.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
from jax.sharding import NamedSharding

from repro.models import sharding as shd


def plan_new_mesh(n_devices: int, model_parallel: int) -> Tuple[int, int, int]:
    """Returns (data, model, idle) for the surviving device count."""
    model = min(model_parallel, n_devices)
    data = max(n_devices // model, 1)
    idle = n_devices - data * model
    return data, model, idle


def remesh(devices, model_parallel: int):
    data, model, idle = plan_new_mesh(len(devices), model_parallel)
    import numpy as np
    dev_grid = np.array(devices[: data * model]).reshape(data, model)
    mesh = jax.sharding.Mesh(dev_grid, ("data", "model"))
    return mesh, idle


def reshard_state(state: Any, cfg, shapes, new_mesh) -> Any:
    """Move (params, opt_state) onto the new mesh (survivor path)."""
    pspecs = shd.param_pspecs(cfg, shapes, new_mesh)

    def to_sharding(spec):
        return NamedSharding(new_mesh, spec)

    params, opt_state = state
    params = jax.device_put(params, jax.tree.map(to_sharding, pspecs))
    if opt_state is not None:
        from repro.train.optim import AdamWState
        from jax.sharding import PartitionSpec as P
        ospec = AdamWState(step=to_sharding(P()),
                           mu=jax.tree.map(to_sharding, pspecs),
                           nu=jax.tree.map(to_sharding, pspecs))
        opt_state = jax.device_put(opt_state, ospec)
    return params, opt_state
