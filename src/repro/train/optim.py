"""AdamW over arbitrary pytrees (no optax in this container).

States are pytrees mirroring the params, so pjit shards them identically to
the parameters (first/second moments inherit the param PartitionSpecs).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: float = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip: float | None = 1.0
    # bf16 moments halve optimizer HBM residency — the difference between
    # fitting and not fitting the 200B+ models on 16GB v5e chips
    # (EXPERIMENTS §Perf iteration C3); math still runs in f32.
    moment_dtype: str = "float32"

    def _mdt(self):
        return jnp.bfloat16 if self.moment_dtype == "bfloat16" else jnp.float32

    def init(self, params) -> AdamWState:
        zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=self._mdt()),
                             params)
        return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                          nu=jax.tree.map(jnp.copy, zeros))

    def update(self, grads, state: AdamWState, params):
        step = state.step + 1
        if self.grad_clip is not None:
            gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                                 for g in jax.tree.leaves(grads)) + 1e-12)
            scale = jnp.minimum(1.0, self.grad_clip / gnorm)
            grads = jax.tree.map(lambda g: g * scale, grads)
        b1, b2 = self.b1, self.b2
        mdt = self._mdt()
        mu = jax.tree.map(
            lambda m, g: (b1 * m.astype(jnp.float32)
                          + (1 - b1) * g.astype(jnp.float32)).astype(mdt),
            state.mu, grads)
        nu = jax.tree.map(
            lambda v, g: (b2 * v.astype(jnp.float32) + (1 - b2)
                          * jnp.square(g.astype(jnp.float32))).astype(mdt),
            state.nu, grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(p, m, v):
            mhat = m.astype(jnp.float32) / bc1
            vhat = v.astype(jnp.float32) / bc2
            delta = mhat / (jnp.sqrt(vhat) + self.eps)
            if self.weight_decay:
                delta = delta + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - self.lr * delta).astype(p.dtype)

        new_params = jax.tree.map(upd, params, mu, nu)
        return new_params, AdamWState(step=step, mu=mu, nu=nu)
