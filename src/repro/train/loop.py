"""Training loop with checkpoint/restart, preemption handling, straggler
watchdog hooks, and periodic eval. Runs on any mesh (CPU host mesh in tests,
the production mesh on a fleet)."""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.data.tokens import TokenPipeline
from repro.models import lm
from repro.models.config import ModelConfig
from repro.train import checkpoint as ckpt
from repro.train.optim import AdamW
from repro.train.stragglers import PreemptionGuard, StragglerWatchdog


@dataclasses.dataclass
class TrainResult:
    step: int
    losses: list
    preempted: bool = False
    resumed_from: Optional[int] = None


def train(cfg: ModelConfig, *, steps: int, batch: int, seq: int,
          ckpt_dir: Optional[str] = None, ckpt_every: int = 50,
          microbatches: int = 1, lr: float = 3e-4, seed: int = 0,
          guard: Optional[PreemptionGuard] = None,
          hook: Optional[Callable[[int, Dict], None]] = None) -> TrainResult:
    opt = AdamW(lr=lr)
    params = lm.init_params(cfg, jax.random.PRNGKey(seed))
    opt_state = opt.init(params)
    pipe = TokenPipeline(vocab=cfg.vocab, batch=batch, seq=seq, seed=seed)
    step_fn = jax.jit(lm.make_train_step(cfg, opt, microbatches=microbatches))
    start = 0
    resumed_from = None
    if ckpt_dir is not None:
        last = ckpt.latest_step(ckpt_dir)
        if last is not None:
            (params, opt_state, pipe_state), start = ckpt.restore(
                ckpt_dir, (params, opt_state, (0, 0)), cfg=cfg)
            pipe.restore(tuple(int(x) for x in jax.tree.leaves(pipe_state)))
            resumed_from = start
    losses = []
    preempted = False
    for step in range(start, steps):
        t0 = time.perf_counter()
        batch_np = pipe.next_batch()
        batch_dev = {k: jnp.asarray(v) for k, v in batch_np.items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch_dev)
        loss = float(metrics["loss"])
        losses.append(loss)
        if hook:
            hook(step, {"loss": loss, "dt": time.perf_counter() - t0})
        should_ckpt = ckpt_dir is not None and (
            (step + 1) % ckpt_every == 0
            or (guard is not None and guard.preempted))
        if should_ckpt:
            ckpt.save(ckpt_dir, step + 1,
                      (params, opt_state, pipe.state()), cfg=cfg)
        if guard is not None and guard.preempted:
            preempted = True
            break
    return TrainResult(step=step + 1 if steps > start else start,
                       losses=losses, preempted=preempted,
                       resumed_from=resumed_from)
