"""Training substrate: optimizer, checkpointing, fault tolerance, elastic
re-meshing, gradient compression, straggler mitigation."""
