"""Micro-batch scheduler: group pending requests by plan signature.

Admission policy (in the spirit of ``launch/serve.py``'s continuous-batching
loop): a signature group is dispatched as soon as it reaches
``max_batch_size`` requests, or once its oldest member has waited
``max_wait_s`` — whichever comes first. Bounded wait keeps tail latency
proportional to the wait budget; bounded size keeps the set of distinct
vmapped executables (one per batch size, see
``PlanCache.get_or_compile_batched``) small.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict, deque
from typing import Deque, Dict, List

from repro.serving.request import QueryRequest


@dataclasses.dataclass
class MicroBatch:
    """One dispatchable group of same-signature requests."""
    key: str
    requests: List[QueryRequest]
    # realization the executor actually served this batch with (stamped by
    # BatchedExecutor.dispatch; the server folds them into SignatureStats)
    sharded: bool = False
    partitioned: bool = False

    def __len__(self) -> int:
        return len(self.requests)


@dataclasses.dataclass
class _Group:
    requests: Deque[QueryRequest]

    @property
    def oldest_t(self) -> float:
        return self.requests[0].submit_t


class MicroBatcher:
    def __init__(self, max_batch_size: int = 8, max_wait_s: float = 2e-3):
        if max_batch_size < 1:
            raise ValueError("max_batch_size must be >= 1")
        self.max_batch_size = max_batch_size
        self.max_wait_s = max_wait_s
        self._groups: "OrderedDict[str, _Group]" = OrderedDict()
        self.groups_formed = 0          # micro-batches dispatched so far
        self.requests_admitted = 0

    # -- admission ---------------------------------------------------------
    def add(self, req: QueryRequest) -> None:
        group = self._groups.get(req.key)
        if group is None:
            group = self._groups[req.key] = _Group(requests=deque())
        group.requests.append(req)
        self.requests_admitted += 1

    def pending(self) -> int:
        return sum(len(g.requests) for g in self._groups.values())

    # -- dispatch decisions ------------------------------------------------
    def pop_ready(self, now: float) -> List[MicroBatch]:
        """Groups that hit the size cap or exceeded the wait deadline.

        A group larger than ``max_batch_size`` is split; the remainder keeps
        its arrival order and original timestamps (so its own deadline still
        counts from the oldest left-behind request).
        """
        ready: List[MicroBatch] = []
        for key in list(self._groups):
            group = self._groups[key]
            while len(group.requests) >= self.max_batch_size:
                ready.append(self._take(key, group, self.max_batch_size))
            if group.requests and now - group.oldest_t >= self.max_wait_s:
                ready.append(self._take(key, group, len(group.requests)))
            if not group.requests:
                del self._groups[key]
        return ready

    def pop_all(self) -> List[MicroBatch]:
        """Flush everything regardless of deadlines (server drain)."""
        ready: List[MicroBatch] = []
        for key in list(self._groups):
            group = self._groups[key]
            while group.requests:
                ready.append(self._take(key, group,
                                        min(len(group.requests),
                                            self.max_batch_size)))
            del self._groups[key]
        return ready

    def _take(self, key: str, group: _Group, n: int) -> MicroBatch:
        batch = MicroBatch(key=key,
                           requests=[group.requests.popleft() for _ in range(n)])
        self.groups_formed += 1
        return batch
