"""Batched executor: one vmapped dispatch per same-signature micro-batch.

Feeds the batch's table pytrees to the cached executable from
``PlanCache.get_or_compile_batched`` (which stacks them on a leading axis,
runs the ``jax.vmap``ped plan body, and unstacks per-request results — all
inside one jitted dispatch). Singleton batches take the plain cached
executable — they share it with non-batched traffic, so a signature's first
lonely request doesn't compile a B=1 vmap variant nobody else will use.
"""
from __future__ import annotations

import time
from typing import Callable, Optional

import jax

from repro.core.plan_cache import PlanCache
from repro.serving.batcher import MicroBatch


class BatchedExecutor:
    def __init__(self, cache: Optional[PlanCache] = None,
                 backend: Optional[str] = None,
                 clock: Callable[[], float] = time.monotonic):
        self.cache = cache or PlanCache()
        self.backend = backend
        self.clock = clock  # same timebase as request timestamps
        self.dispatches = 0
        self.batched_dispatches = 0

    def dispatch(self, batch: MicroBatch, now: float) -> float:
        """Execute the micro-batch; fill each request's result. Returns the
        duration of the (blocking) dispatch on the executor's clock."""
        reqs = batch.requests
        rep = reqs[0]  # same signature => same compiled program; any member
        t0 = self.clock()
        if len(reqs) == 1:
            run = self.cache.get_or_compile(rep.plan, rep.catalog,
                                            backend=self.backend,
                                            cache_key=batch.key)
            out = run(rep.tables)
            jax.block_until_ready(out)
            results = [out]
        else:
            run = self.cache.get_or_compile_batched(rep.plan, rep.catalog,
                                                    len(reqs),
                                                    backend=self.backend,
                                                    cache_key=batch.key)
            results = run(tuple(r.tables for r in reqs))
            jax.block_until_ready(results)
            self.batched_dispatches += 1
        dt = self.clock() - t0
        self.dispatches += 1
        for req, res in zip(reqs, results):
            req.result = res
            req.done = True
            req.dispatch_t = now
            req.finish_t = now + dt
            req.batch_size = len(reqs)
        return dt
