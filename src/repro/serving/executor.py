"""Batched executor: one dispatch per same-signature micro-batch.

Feeds the batch's table pytrees to the cached executable from
``PlanCache.get_or_compile_batched`` (which stacks them on a leading axis,
runs the ``jax.vmap``ped plan body, and unstacks per-request results — all
inside one jitted dispatch). Singleton batches take the plain cached
executable — they share it with non-batched traffic, so a signature's first
lonely request doesn't compile a B=1 vmap variant nobody else will use.

With a ``mesh``, eligible batches (more than one device and a batch size the
device count divides — ``core.mesh.can_shard``) take the *sharded*
executable instead (``PlanCache.get_or_compile_sharded``): the stacked batch
axis is split over the mesh's data axis, one slice per device. Ineligible
batches fall back to the single-device vmapped program automatically. An
explicit node-level ``backend`` override ('jnp'/'pallas') takes precedence
over the mesh: the sharded realization lowers per-node to jnp, so honoring
the override means not sharding.

Requests the server flagged *partitioned* — oversized single queries whose
working set busts the per-device memory budget — take
``PlanCache.get_or_compile_partitioned`` instead: one intra-query-sharded
dispatch per request (operators partitioned over the mesh, no batch axis),
executed sequentially within the group.

All request timestamps (``dispatch_t``, ``finish_t``) come from the
executor's own single clock read bracketing the dispatch, so
``finish_t - dispatch_t`` equals the measured dispatch duration exactly —
no skew against a caller's earlier clock read.
"""
from __future__ import annotations

import time
from typing import Callable, Optional

import jax

from repro.core import costed_lowering
from repro.core import mesh as mesh_util
from repro.core.plan_cache import LRUCache, PlanCache
from repro.serving.batcher import MicroBatch


class BatchedExecutor:
    def __init__(self, cache: Optional[PlanCache] = None,
                 backend: Optional[str] = None,
                 mesh=None,
                 clock: Callable[[], float] = time.monotonic):
        self.cache = cache or PlanCache()
        self.backend = backend  # node-level lowering override (jnp/pallas)
        self.mesh = mesh        # multi-device batch sharding, when eligible
        self.clock = clock  # same timebase as request timestamps
        self.dispatches = 0
        self.batched_dispatches = 0
        self.sharded_dispatches = 0
        self.partitioned_dispatches = 0
        # vmapped-vs-sharded is a costed decision (the shared oracle against
        # the cache's profile); memoized off the dispatch path per
        # (signature, batch size, profile epoch)
        self._realization_memo = LRUCache(256)

    def _use_sharded(self, batch: MicroBatch) -> bool:
        reqs = batch.requests
        if (len(reqs) <= 1 or self.backend is not None
                or not mesh_util.can_shard(self.mesh, len(reqs))):
            return False
        mk = (batch.key, len(reqs), self.cache.profile_epoch)
        dec = self._realization_memo.get(mk)
        if dec is None:
            dec = costed_lowering.choose_batch_realization(
                reqs[0].plan, reqs[0].catalog, len(reqs), self.mesh,
                profile=self.cache.profile)
            self._realization_memo.put(mk, dec)
        return dec == "sharded"

    def dispatch(self, batch: MicroBatch) -> float:
        """Execute the micro-batch; fill each request's result. Returns the
        duration of the (blocking) dispatch on the executor's clock."""
        reqs = batch.requests
        rep = reqs[0]  # same signature => same compiled program; any member
        # oversized single queries (flagged at admission: working set busts
        # the per-device budget) take the partitioned executable — one
        # intra-query-sharded dispatch per request
        partitioned = rep.partitioned and self.mesh is not None
        # an explicit node-level backend override disables sharding: the
        # sharded realization lowers per-node to jnp, and silently serving
        # the same signature with different kernel realizations depending on
        # batch size would discard the caller's choice exactly on the hot
        # (grouped) traffic. Eligible batches still go through the cost
        # oracle: sharding only when the profile predicts it pays.
        sharded = (not partitioned) and self._use_sharded(batch)
        batch.sharded, batch.partitioned = sharded, partitioned
        t0 = self.clock()
        if partitioned:
            # the caller's node-level kernel override constrains the
            # partitioned lowering too — partitioning is a distribution
            # choice, not a kernel one, so the two compose
            run = self.cache.get_or_compile_partitioned(
                rep.plan, rep.catalog, self.mesh, backend=self.backend,
                cache_key=batch.key)
            results = [run(r.tables) for r in reqs]
            jax.block_until_ready(results)
            # per completed *batch*, like every other dispatch counter
            self.partitioned_dispatches += 1
        elif len(reqs) == 1:
            run = self.cache.get_or_compile(rep.plan, rep.catalog,
                                            backend=self.backend,
                                            cache_key=batch.key)
            out = run(rep.tables)
            jax.block_until_ready(out)
            results = [out]
        else:
            if sharded:
                run = self.cache.get_or_compile_sharded(
                    rep.plan, rep.catalog, len(reqs), self.mesh,
                    cache_key=batch.key)
            else:
                run = self.cache.get_or_compile_batched(
                    rep.plan, rep.catalog, len(reqs), backend=self.backend,
                    cache_key=batch.key)
            results = run(tuple(r.tables for r in reqs))
            jax.block_until_ready(results)
            # counters record *completed* dispatches only — a raising
            # dispatch is the server's failure path, not a sharded/batched one
            self.batched_dispatches += 1
            if sharded:
                self.sharded_dispatches += 1
        dt = self.clock() - t0
        self.dispatches += 1
        for req, res in zip(reqs, results):
            req.result = res
            req.done = True
            req.dispatch_t = t0
            req.finish_t = t0 + dt
            req.batch_size = len(reqs)
        return dt
