"""In-flight query requests: the unit the serving tier admits and batches.

A ``QueryRequest`` is one ``(plan, tables)`` pair plus bookkeeping. The
``tables`` payload defaults to the catalog's own tables but is usually a
fresh same-schema dict — the parameterized-traffic case the compiled-plan
cache exists for. Requests with equal signature keys (``PlanCache.key``)
are guaranteed to share one compiled executable and may be vmapped together.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.core import ir
from repro.relational.table import Table


@dataclasses.dataclass
class QueryRequest:
    rid: int
    plan: ir.Plan
    catalog: ir.Catalog
    tables: Dict[str, Table]
    key: str = ""                   # PlanCache signature (set by the server)
    submit_t: float = 0.0           # server-clock timestamps
    dispatch_t: float = 0.0
    finish_t: float = 0.0
    batch_size: int = 0             # occupancy of the batch that served it
    # oversized single query: its plan's working set busts the per-device
    # memory budget, so it is keyed and served through the *partitioned*
    # executable (PlanCache.get_or_compile_partitioned) instead of being
    # refused or thrashing a single device
    partitioned: bool = False
    result: Optional[Table] = None
    done: bool = False
    error: Optional[str] = None     # set instead of result if dispatch failed

    @property
    def queue_wait_s(self) -> float:
        return max(0.0, self.dispatch_t - self.submit_t)

    @property
    def latency_s(self) -> float:
        return max(0.0, self.finish_t - self.submit_t)
