"""QueryServer: the serving tier's front-end.

``submit`` admits an in-flight ``(plan, tables)`` pair asynchronously (it
returns a pending ``QueryRequest`` immediately); ``step`` lets the
micro-batch scheduler dispatch every signature group that hit its admission
policy; ``drain`` flushes the rest. Per-signature traffic statistics
(request counts, batch occupancy, dispatch latency) accumulate in
``SignatureStats`` and are exported to the optimizer-feedback channel by
``repro.serving.feedback``.

The clock is injectable (``clock=``) so schedulers and tests can drive
deadlines deterministically; the default is ``time.monotonic``.
"""
from __future__ import annotations

import dataclasses
import time
import weakref
from typing import Callable, Dict, Optional

from repro.core import ir
from repro.core.plan_cache import LRUCache, PlanCache, scan_table_names
from repro.relational.table import Table
from repro.serving.batcher import MicroBatcher
from repro.serving.executor import BatchedExecutor
from repro.serving.request import QueryRequest


@dataclasses.dataclass
class SignatureStats:
    """Per-signature serving statistics (the feedback channel's payload)."""
    key: str
    requests: int = 0               # everything submitted, incl. pending
    served_requests: int = 0        # successfully dispatched requests only
    dispatches: int = 0
    batched_requests: int = 0       # requests served in a batch of >= 2
    sharded_dispatches: int = 0     # dispatches served multi-device (batch
    partitioned_dispatches: int = 0  # axis sharded / operators partitioned)
    ways: int = 0                   # mesh device count of those dispatches
    failures: int = 0               # requests whose dispatch raised
    total_dispatch_s: float = 0.0
    total_wait_s: float = 0.0
    # representative query for this signature: lets the feedback channel
    # re-optimize what the serving tier actually sees most
    plan: Optional[ir.Plan] = None
    catalog: Optional[ir.Catalog] = None

    @property
    def mean_occupancy(self) -> float:
        # served / dispatches: pending submissions and failed batches never
        # rode a dispatch, so counting them (as `requests` would) inflates
        # the occupancy the MCTS feedback channel prioritizes by
        return (self.served_requests / self.dispatches
                if self.dispatches else 0.0)

    @property
    def mean_dispatch_s(self) -> float:
        return (self.total_dispatch_s / self.dispatches
                if self.dispatches else 0.0)

    @property
    def mean_wait_s(self) -> float:
        """Mean queueing delay (submit -> dispatch) of served requests: the
        admission-policy pressure signal warm-start prioritization reads."""
        return (self.total_wait_s / self.served_requests
                if self.served_requests else 0.0)

    def as_dict(self) -> Dict[str, float]:
        return {"requests": self.requests,
                "served_requests": self.served_requests,
                "dispatches": self.dispatches,
                "batched_requests": self.batched_requests,
                "sharded_dispatches": self.sharded_dispatches,
                "partitioned_dispatches": self.partitioned_dispatches,
                "ways": self.ways,
                "mean_occupancy": self.mean_occupancy,
                "mean_dispatch_s": self.mean_dispatch_s,
                "mean_wait_s": self.mean_wait_s}


class QueryServer:
    def __init__(self, cache: Optional[PlanCache] = None,
                 max_batch_size: int = 8, max_wait_s: float = 2e-3,
                 backend: Optional[str] = None, mesh=None,
                 memory_budget: Optional[float] = None,
                 clock: Callable[[], float] = time.monotonic):
        self.cache = cache or PlanCache()
        self.batcher = MicroBatcher(max_batch_size=max_batch_size,
                                    max_wait_s=max_wait_s)
        # mesh: multi-device batch sharding — eligible micro-batches take
        # the backend="sharded" executable (see BatchedExecutor.dispatch)
        self.executor = BatchedExecutor(self.cache, backend=backend,
                                        mesh=mesh, clock=clock)
        self.mesh = mesh
        from repro.core import mesh as mesh_util
        self._ways = mesh_util.batch_ways(mesh) if mesh is not None else 1
        # per-device working-set budget: installed on the cache's profile,
        # so every costed-lowering decision this server triggers sees it.
        # A submitted plan that busts it is routed to the *partitioned*
        # executable (operators sharded over the mesh) instead of being
        # served on one device (thrashing) or refused.
        if memory_budget is not None:
            self.cache.profile.memory_budget = float(memory_budget)
        self.clock = clock
        self.signatures: Dict[str, SignatureStats] = {}
        self.completed = 0
        self.failed = 0
        self._next_rid = 0
        # memoizes (key, scanned names) per (plan, catalog) object identity:
        # parameterized traffic re-submits the same plan objects, and the
        # full signature walk is too expensive for the per-request path.
        # Entries hold weakrefs (a live ref pins the id; a dead ref or an
        # identity mismatch is a miss), so the memo never keeps retired
        # plans or their catalogs' table payloads alive.
        self._submit_memo = LRUCache(maxsize=1024)

    # -- admission ---------------------------------------------------------
    def submit(self, plan: ir.Plan, catalog: ir.Catalog,
               tables: Optional[Dict[str, Table]] = None) -> QueryRequest:
        """Admit one in-flight query; returns immediately with a pending
        request whose ``result`` is filled by a later ``step``/``drain``."""
        if tables is None:
            tables = dict(catalog.tables)
        memo = self._submit_memo.get((id(plan), id(catalog)))
        if memo is not None and (memo[0]() is not plan
                                 or memo[1]() is not catalog
                                 # a recalibrated profile can change the
                                 # key's lowering-decision suffix: a stale
                                 # memo must not alias the old executable
                                 or memo[4] != self.cache.profile_epoch):
            memo = None  # id was reused by a different object
        if memo is None:
            # oversized single query: a working set over the per-device
            # budget can't be served on one device — key it (and flag it)
            # for the partitioned executable, whose PartSpec vector rides
            # the key's #cl= decision tokens
            from repro.core import cost as cost_mod
            budget = self.cache.profile.memory_budget
            partitioned = (
                self._ways > 1 and budget is not None
                and cost_mod.plan_peak_memory(plan, catalog,
                                              self.cache.profile) > budget)
            key = (self.cache.key(plan, catalog, mesh=self.mesh,
                                  backend=self.executor.backend)
                   if partitioned else self.cache.key(plan, catalog))
            memo = (weakref.ref(plan), weakref.ref(catalog), key,
                    scan_table_names(plan), self.cache.profile_epoch,
                    partitioned)
            self._submit_memo.put((id(plan), id(catalog)), memo)
        _, _, key, scanned, _, partitioned = memo
        # ship only the tables the plan scans: the batched executor stacks
        # every leaf of every request, so catalog tables the query never
        # touches would be pure copy overhead on the dispatch path
        req = QueryRequest(rid=self._next_rid, plan=plan, catalog=catalog,
                           tables={k: tables[k] for k in scanned},
                           key=key, submit_t=self.clock(),
                           partitioned=partitioned)
        self._next_rid += 1
        sig = self.signatures.get(req.key)
        if sig is None:
            sig = self.signatures[req.key] = SignatureStats(
                key=req.key, plan=plan, catalog=catalog)
        sig.requests += 1
        self.batcher.add(req)
        return req

    # -- dispatch ----------------------------------------------------------
    def step(self) -> int:
        """Dispatch every signature group that satisfies the admission
        policy (size cap reached or wait deadline expired). Returns the
        number of requests completed this step."""
        return self._dispatch(self.batcher.pop_ready(self.clock()))

    def drain(self) -> int:
        """Flush all pending requests regardless of deadlines."""
        return self._dispatch(self.batcher.pop_all())

    def _dispatch(self, batches) -> int:
        done = 0
        for batch in batches:
            sig = self.signatures[batch.key]
            try:
                dt = self.executor.dispatch(batch)
            except Exception as e:  # noqa: BLE001 — a bad payload (e.g.
                # tables whose shapes disagree with the signature's schema)
                # must fail its own batch, not hang its requests forever or
                # take the serving loop down with them
                now = self.clock()
                for req in batch.requests:
                    req.done = True
                    req.error = f"{type(e).__name__}: {e}"
                    req.dispatch_t = req.finish_t = now
                sig.failures += len(batch)
                self.failed += len(batch)
                continue
            sig.dispatches += 1
            sig.served_requests += len(batch)
            sig.total_dispatch_s += dt
            if batch.sharded:
                sig.sharded_dispatches += 1
                sig.ways = self._ways
            if batch.partitioned:
                sig.partitioned_dispatches += 1
                sig.ways = self._ways
            for req in batch.requests:
                sig.total_wait_s += req.queue_wait_s
                if req.batch_size >= 2:
                    sig.batched_requests += 1
            done += len(batch)
        self.completed += done
        return done

    # -- introspection -----------------------------------------------------
    def pending(self) -> int:
        return self.batcher.pending()

    def stats(self) -> Dict[str, float]:
        sigs = self.signatures.values()
        total_disp = sum(s.dispatches for s in sigs)
        return {
            "completed": self.completed,
            "failed": self.failed,
            "pending": self.batcher.pending(),
            "signatures": len(self.signatures),
            "groups_formed": self.batcher.groups_formed,
            "dispatches": total_disp,
            "sharded_dispatches": self.executor.sharded_dispatches,
            "partitioned_dispatches": self.executor.partitioned_dispatches,
            "mean_occupancy": (self.completed / total_disp
                               if total_disp else 0.0),
            "cache": self.cache.stats.as_dict(),
            "traces": self.cache.traces,
        }
