"""Serving tier: signature-grouped micro-batching over the compiled-plan cache.

The paper's headline traffic — thousands of parameterized queries that
cluster into a handful of structural signatures — enters through
``QueryServer.submit``; the micro-batch scheduler (``MicroBatcher``) groups
in-flight requests by their ``PlanCache.key()`` signature, and the batched
executor stacks each group's table pytrees on a leading axis and runs them
as one ``jax.vmap``ped dispatch of the cached executable — or, given a
device mesh (``QueryServer(..., mesh=)``), as one ``shard_map``ped dispatch
that splits the stacked batch axis over the mesh's data axis
(``backend="sharded"``; see ``repro.core.mesh``). Per-signature hit/latency
statistics flow back into ``ReusableMCTS`` warm-starts through
``repro.serving.feedback``.
"""
from repro.serving.request import QueryRequest
from repro.serving.batcher import MicroBatch, MicroBatcher
from repro.serving.executor import BatchedExecutor
from repro.serving.server import QueryServer, SignatureStats
from repro.serving.feedback import SignatureExport, warm_start_from_server

__all__ = [
    "QueryRequest", "MicroBatch", "MicroBatcher", "BatchedExecutor",
    "QueryServer", "SignatureStats", "SignatureExport",
    "warm_start_from_server",
]
