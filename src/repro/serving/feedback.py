"""Feedback channel: serving-tier signature statistics -> optimizer warm-starts.

Closes the ROADMAP loop "feed cache hit statistics back into ReusableMCTS
warm-starts": the signatures the server actually sees — weighted by traffic
volume x dispatch latency, i.e. where optimization time pays off — are
re-optimized once against their representative plan. Each such run
populates the optimizer's embedding-keyed global node store
(``core/mcts.py`` ``NodeIndex``), so the *next* query of that family
(including parameter variants whose exact signature differs but whose
Query2Vec embedding collides) starts from a warm root and needs only
``warm_iterations`` instead of a cold full search.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from repro.core import ir
from repro.core.mcts import ReusableMCTS
from repro.serving.server import QueryServer


@dataclasses.dataclass
class SignatureExport:
    """One serving signature's traffic summary, with its representative
    query attached so the optimizer can replay it."""
    key: str
    requests: int
    dispatches: int
    mean_occupancy: float
    mean_dispatch_s: float
    # mean queueing delay (submit -> dispatch): signatures under batching
    # pressure wait longer, which warm-start prioritization should see
    mean_wait_s: float
    plan: ir.Plan
    catalog: ir.Catalog

    @property
    def weight(self) -> float:
        """Traffic volume x unit latency (dispatch + queueing): expected
        user-visible seconds this signature costs the fleet, the natural
        priority for optimizer attention. Queueing pressure counts — a
        signature whose requests sit in the batcher is hurting tail latency
        even when its dispatches are cheap."""
        return self.requests * max(self.mean_dispatch_s + self.mean_wait_s,
                                   1e-9)


def export_signature_stats(server: QueryServer) -> List[SignatureExport]:
    """Snapshot the server's per-signature stats, heaviest traffic first."""
    exports = [
        SignatureExport(key=s.key, requests=s.requests,
                        dispatches=s.dispatches,
                        mean_occupancy=s.mean_occupancy,
                        mean_dispatch_s=s.mean_dispatch_s,
                        mean_wait_s=s.mean_wait_s,
                        plan=s.plan, catalog=s.catalog)
        for s in server.signatures.values()
        if s.plan is not None and s.dispatches > 0
    ]
    exports.sort(key=lambda e: -e.weight)
    return exports


def warm_start_from_server(mcts: ReusableMCTS,
                           exports: List[SignatureExport],
                           top_k: int = 4) -> Dict[str, object]:
    """Prime the reusable optimizer's node store from server traffic.

    Runs one full optimization per hot signature (heaviest ``top_k`` by
    ``weight``). The visits land in the shared ``NodeIndex``-backed store,
    so subsequent same-family queries collide with a well-visited root and
    take the warm path (fewer iterations, exploit known-good actions first).
    Returns a summary of what was primed.
    """
    primed = []
    for e in exports[:top_k]:
        _, stats = mcts.optimize(e.plan, e.catalog)
        primed.append({"key": e.key, "requests": e.requests,
                       "weight": e.weight,
                       "best_cost": stats["best_cost"],
                       "iterations": stats["iterations"]})
    return {"primed": primed, "store_nodes": len(mcts.nodes),
            "store_bytes": mcts.storage_bytes()}
