"""Feedback channel: serving-tier signature statistics -> optimizer warm-starts
and cost-oracle calibration.

Closes the ROADMAP loop "feed cache hit statistics back into ReusableMCTS
warm-starts": the signatures the server actually sees — weighted by traffic
volume x dispatch latency, i.e. where optimization time pays off — are
re-optimized once against their representative plan. Each such run
populates the optimizer's embedding-keyed global node store
(``core/mcts.py`` ``NodeIndex``), so the *next* query of that family
(including parameter variants whose exact signature differs but whose
Query2Vec embedding collides) starts from a warm root and needs only
``warm_iterations`` instead of a cold full search.

The same statistics also sharpen the *analytic* oracle online:
``calibrate_profile`` least-squares-fits the device profile's
``peak_flops`` / ``hbm_bw`` / ``op_overhead_s`` against measured
per-signature dispatch latencies (via ``cost.plan_cost_breakdown``'s
linearized predictions), and ``apply_calibration`` installs the fitted
profile into a ``PlanCache`` — whose costed lowering then re-derives its
decisions under the new profile (``PlanCache.recalibrate`` bumps the
profile epoch, so a changed decision selects a fresh executable instead of
aliasing a stale one). Serving traffic thereby sharpens future lowering
decisions.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from repro.core import cost, ir
from repro.core.mcts import ReusableMCTS
from repro.core.plan_cache import PlanCache
from repro.serving.server import QueryServer


@dataclasses.dataclass
class SignatureExport:
    """One serving signature's traffic summary, with its representative
    query attached so the optimizer can replay it."""
    key: str
    requests: int
    dispatches: int
    mean_occupancy: float
    mean_dispatch_s: float
    # mean queueing delay (submit -> dispatch): signatures under batching
    # pressure wait longer, which warm-start prioritization should see
    mean_wait_s: float
    plan: ir.Plan
    catalog: ir.Catalog
    # multi-device traffic: how many dispatches ran sharded/partitioned and
    # over how many devices — the calibration features of the profile's
    # collective_overhead_s (single-device signatures leave them 0)
    sharded_dispatches: int = 0
    partitioned_dispatches: int = 0
    ways: int = 0

    @property
    def weight(self) -> float:
        """Traffic volume x unit latency (dispatch + queueing): expected
        user-visible seconds this signature costs the fleet, the natural
        priority for optimizer attention. Queueing pressure counts — a
        signature whose requests sit in the batcher is hurting tail latency
        even when its dispatches are cheap."""
        return self.requests * max(self.mean_dispatch_s + self.mean_wait_s,
                                   1e-9)


def export_signature_stats(server: QueryServer) -> List[SignatureExport]:
    """Snapshot the server's per-signature stats, heaviest traffic first."""
    exports = [
        SignatureExport(key=s.key, requests=s.requests,
                        dispatches=s.dispatches,
                        mean_occupancy=s.mean_occupancy,
                        mean_dispatch_s=s.mean_dispatch_s,
                        mean_wait_s=s.mean_wait_s,
                        plan=s.plan, catalog=s.catalog,
                        sharded_dispatches=s.sharded_dispatches,
                        partitioned_dispatches=s.partitioned_dispatches,
                        ways=s.ways)
        for s in server.signatures.values()
        if s.plan is not None and s.dispatches > 0
    ]
    exports.sort(key=lambda e: -e.weight)
    return exports


def warm_start_from_server(mcts: ReusableMCTS,
                           exports: List[SignatureExport],
                           top_k: int = 4) -> Dict[str, object]:
    """Prime the reusable optimizer's node store from server traffic.

    Runs one full optimization per hot signature (heaviest ``top_k`` by
    ``weight``). The visits land in the shared ``NodeIndex``-backed store,
    so subsequent same-family queries collide with a well-visited root and
    take the warm path (fewer iterations, exploit known-good actions first).
    Returns a summary of what was primed.
    """
    primed = []
    for e in exports[:top_k]:
        _, stats = mcts.optimize(e.plan, e.catalog)
        primed.append({"key": e.key, "requests": e.requests,
                       "weight": e.weight,
                       "best_cost": stats["best_cost"],
                       "iterations": stats["iterations"]})
    return {"primed": primed, "store_nodes": len(mcts.nodes),
            "store_bytes": mcts.storage_bytes()}


# ---------------------------------------------------------------------------
# analytic-oracle calibration from measured dispatch latencies
# ---------------------------------------------------------------------------

def calibrate_profile(exports: List[SignatureExport],
                      profile: Optional[cost.DeviceProfile] = None,
                      *, l2: float = 0.1) -> cost.CalibrationFit:
    """Refit the device profile against measured serving latencies.

    Each served signature contributes one sample: the analytic resource
    breakdown of its representative plan scaled to the signature's mean
    batch occupancy (data traffic and FLOPs ride the batch axis, weights
    stream once per dispatch) against its measured mean dispatch seconds,
    weighted by dispatch count. Signatures whose dispatches ran
    predominantly multi-device (sharded batch axis or partitioned
    operators) are modeled like ``cost.batched_plan_cost`` models them:
    per-shard data scale ``occupancy / ways`` plus ``ways`` collective
    launches — which is what identifies ``collective_overhead_s``
    alongside ``peak_flops`` / ``hbm_bw`` / ``op_overhead_s`` (an all-zero
    ``n_coll`` column leaves it at the prior). The fit solves the
    four-coefficient system with a ridge pull toward the prior — see
    ``cost.fit_profile``.
    """
    profile = profile or cost.default_profile()
    samples = []
    for e in exports:
        if e.dispatches <= 0 or e.mean_dispatch_s <= 0:
            continue
        b = cost.plan_cost_breakdown(e.plan, e.catalog, profile)
        multi = e.sharded_dispatches + e.partitioned_dispatches
        ways = e.ways if (e.ways > 1 and 2 * multi >= e.dispatches) else 1
        sample = b.scaled(max(e.mean_occupancy, 1.0) / ways)
        if ways > 1:
            sample = dataclasses.replace(sample,
                                         n_coll=sample.n_coll + float(ways))
        samples.append((sample, e.mean_dispatch_s, float(e.dispatches)))
    return cost.fit_profile(samples, profile, l2=l2)


def apply_calibration(cache: PlanCache, exports: List[SignatureExport],
                      *, l2: float = 0.1) -> cost.CalibrationFit:
    """Calibrate against the cache's current profile and install the fit.

    ``PlanCache.recalibrate`` bumps the profile epoch: every signature's
    lowering decisions are re-derived on its next dispatch, and a changed
    realization vector changes the executable key — serving traffic
    sharpens future lowering decisions without stale-executable aliasing.
    """
    fit = calibrate_profile(exports, cache.profile, l2=l2)
    if fit.n_samples:
        cache.recalibrate(fit.profile)
    return fit
