"""Trip-count-aware HLO cost analysis.

XLA's built-in ``compiled.cost_analysis()`` counts each while-loop body ONCE
regardless of trip count (verified empirically), which under-reports every
scanned computation (layer stacks, microbatches, flash-attention chunks) by
orders of magnitude — and the same under-count hits collective traffic inside
loops. This module parses the optimized per-device HLO, recovers loop trip
counts from scan-shaped conditions (induction var LT constant), and computes:

    flops            — dot/elementwise/reduce, loop-multiplied
    bytes            — operand+result bytes at fusion boundaries (HBM proxy)
    collective bytes — per collective kind, loop-multiplied

The model mirrors HloCostAnalysis (dots = 2·prod(out)·prod(contract);
1 flop/element for arithmetic; reduce = input size) so single-body numbers
match XLA's, while loops are handled correctly.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 1, "u4": 1, "pred": 1, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(
    r"(f64|f32|f16|bf16|f8e4m3fn|f8e5m2|s64|u64|s32|u32|s16|u16|s8|u8|s4|u4|"
    r"pred|c64|c128)\[([0-9,]*)\]")

_ELEMWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "exponential", "log", "tanh", "rsqrt", "sqrt", "negate", "abs", "sign",
    "floor", "ceil", "round-nearest-afz", "logistic", "cosine", "sine",
    "atan2", "remainder", "select", "clamp", "compare", "and", "or", "xor",
    "not", "exponential-minus-one", "log-plus-one", "cbrt", "erf",
}

_SKIP_BYTES = {"parameter", "constant", "get-tuple-element", "tuple",
               "bitcast", "copy", "copy-start", "copy-done", "after-all",
               "partition-id", "replica-id", "iota", "opt-barrier"}

_COLLECTIVES = {"all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "ragged-all-to-all"}

_OP_LINE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+?)\s+([a-z][\w\-]*)\((.*)$")

_CALLS_RE = re.compile(r"calls=%([\w.\-]+)")
_BODY_RE = re.compile(r"body=%([\w.\-]+)")
_COND_RE = re.compile(r"condition=%([\w.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def _shape_elems_bytes(sig: str) -> Tuple[float, float]:
    elems = 0.0
    byts = 0.0
    for dt, dims in _SHAPE_RE.findall(sig):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        byts += n * _DTYPE_BYTES[dt]
    return elems, byts


@dataclasses.dataclass
class _Op:
    name: str
    sig: str           # result type string
    opcode: str
    rest: str          # operand list + attributes (raw tail of the line)


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    # HBM traffic of attention-score-sized f32 intermediates. At CPU fusion
    # granularity each online-softmax stage materializes the [.., S, chunk]
    # score tile; the TPU flash kernels (kernels/flash_attention) keep these
    # VMEM-resident, so the roofline reports memory_s with and without them.
    score_bytes: float = 0.0
    collectives: Dict[str, float] = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    unknown_loops: int = 0

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.score_bytes += other.score_bytes * mult
        for k, v in other.collectives.items():
            self.collectives[k] += v * mult
        self.unknown_loops += other.unknown_loops


class HloCostModel:
    def __init__(self, hlo_text: str, score_elems_threshold: Optional[float] = None):
        self.computations: Dict[str, List[_Op]] = {}
        self.entry: Optional[str] = None
        self.score_thresh = score_elems_threshold
        self._parse(hlo_text)
        self._memo: Dict[str, Cost] = {}

    def _is_scoreish(self, sig: str) -> bool:
        """Attention score tiles: large (>= S*chunk elems), f32, and >= 4-D
        ([B, Hkv, S, G, C] / bitcast variants) — distinguishes them from
        hidden-sized 3-D activations."""
        if self.score_thresh is None:
            return False
        m = _SHAPE_RE.search(sig)
        if not m:
            return False
        dims = [d for d in m.group(2).split(",") if d]
        if len(dims) < 4:
            return False
        elems, byts = _shape_elems_bytes(sig)
        return elems >= self.score_thresh and byts >= 4 * elems  # f32+

    # -- parsing ------------------------------------------------------------
    def _parse(self, text: str):
        cur: Optional[str] = None
        for line in text.splitlines():
            header = re.match(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{", line)
            if header:
                cur = header.group(2)
                self.computations[cur] = []
                if header.group(1):
                    self.entry = cur
                continue
            if line.startswith("}"):
                cur = None
                continue
            if cur is None:
                continue
            m = _OP_LINE.match(line)
            if m:
                self.computations[cur].append(
                    _Op(name=m.group(1), sig=m.group(2), opcode=m.group(3),
                        rest=m.group(4)))

    def _symtab(self, comp: str) -> Dict[str, str]:
        return {op.name: op.sig for op in self.computations.get(comp, [])}

    # -- trip counts ----------------------------------------------------------
    def _trip_count(self, cond_comp: str) -> Optional[int]:
        consts = []
        for op in self.computations.get(cond_comp, []):
            if op.opcode == "constant":
                m = re.match(r"(\d+)\)", op.rest)
                if m:
                    consts.append(int(m.group(1)))
            consts += [int(c) for c in _CONST_RE.findall(op.rest)]
        # scan-shaped loops compare the induction var LT a constant
        has_lt = any("direction=LT" in op.rest or op.opcode == "compare"
                     or "compare" in op.rest
                     for op in self.computations.get(cond_comp, []))
        # the compare may live in a fused computation referenced from the cond
        for op in self.computations.get(cond_comp, []):
            cm = _CALLS_RE.search(op.rest)
            if cm:
                sub = cm.group(1)
                for sop in self.computations.get(sub, []):
                    if sop.opcode == "compare":
                        has_lt = True
        if has_lt and consts:
            return max(consts)
        return None

    # -- cost ------------------------------------------------------------------
    def cost_of(self, comp: str, top_level: bool = True) -> Cost:
        key = f"{comp}|{top_level}"
        if key in self._memo:
            return self._memo[key]
        total = Cost()
        sym = self._symtab(comp)
        for op in self.computations.get(comp, []):
            oc = op.opcode
            out_elems, out_bytes = _shape_elems_bytes(op.sig)
            if oc == "while":
                body = _BODY_RE.search(op.rest)
                cond = _COND_RE.search(op.rest)
                trips = self._trip_count(cond.group(1)) if cond else None
                if trips is None:
                    trips = 1
                    total.unknown_loops += 1
                if body:
                    total.add(self.cost_of(body.group(1), top_level=True),
                              mult=trips)
                if cond:
                    total.add(self.cost_of(cond.group(1), top_level=True),
                              mult=trips)
                continue
            if oc in ("fusion", "call", "async-start"):
                cm = _CALLS_RE.search(op.rest)
                sub = None
                if cm:
                    sub = self.cost_of(cm.group(1), top_level=False)
                    total.flops += sub.flops
                    for k, v in sub.collectives.items():
                        total.collectives[k] += v
                    total.unknown_loops += sub.unknown_loops
                if top_level:
                    # in-place update fusions (cache writes, .at[].set): XLA
                    # aliases buffer-sized operands with the output — count
                    # only the genuinely-moved small operands (the update
                    # slice), not a full rewrite of the buffer
                    if cm and self._is_inplace_update(cm.group(1)):
                        small = self._operands_below(op, sym, 0.5 * out_bytes)
                        total.bytes += 2 * small
                    else:
                        b = out_bytes + self._operand_bytes(op, sym)
                        total.bytes += b
                        if self._is_scoreish(op.sig):
                            total.score_bytes += b
                continue
            if oc in ("dynamic-slice",):
                # reads only the slice (result-sized), not the full operand
                total.flops += 0.0
                if top_level:
                    total.bytes += 2 * out_bytes
                continue
            if oc in ("dynamic-update-slice",):
                # in-place: read+write the update region only
                upd = self._second_operand_bytes(op, sym)
                if top_level:
                    total.bytes += 2 * upd
                continue
            if oc == "conditional":
                branches = _OPERAND_RE.findall(op.rest)
                sub_costs = [self.cost_of(b) for b in branches
                             if b in self.computations]
                if sub_costs:
                    best = max(sub_costs, key=lambda c: c.flops)
                    total.add(best)
                continue
            if oc.replace("-start", "") in _COLLECTIVES:
                kind = oc.replace("-start", "")
                total.collectives[kind] += out_bytes
                if top_level:
                    total.bytes += out_bytes
                continue
            if oc in ("dot", "dot-general"):
                contract = 1.0
                cm = _CONTRACT_RE.search(op.rest)
                lhs_names = _OPERAND_RE.findall(op.rest)
                if cm and lhs_names:
                    lhs_sig = sym.get(lhs_names[0], "")
                    sm = _SHAPE_RE.search(lhs_sig)
                    if sm:
                        dims = [int(d) for d in sm.group(2).split(",") if d]
                        for idx in cm.group(1).split(","):
                            if idx and int(idx) < len(dims):
                                contract *= dims[int(idx)]
                total.flops += 2.0 * out_elems * contract
                if top_level:
                    b = out_bytes + self._operand_bytes(op, sym)
                    total.bytes += b
                    if self._is_scoreish(op.sig):
                        total.score_bytes += b
                continue
            if oc == "convolution":
                # rough: 2 * out_elems * (kernel elems / out channels)
                total.flops += 2.0 * out_elems
                if top_level:
                    total.bytes += out_bytes + self._operand_bytes(op, sym)
                continue
            if oc in ("reduce", "reduce-window"):
                total.flops += self._operand_elems(op, sym)
                if top_level:
                    total.bytes += out_bytes + self._operand_bytes(op, sym)
                continue
            if oc in _ELEMWISE:
                total.flops += out_elems
                if top_level and oc not in _SKIP_BYTES:
                    b = out_bytes + self._operand_bytes(op, sym)
                    total.bytes += b
                    if self._is_scoreish(op.sig):
                        total.score_bytes += b
                continue
            if top_level and oc not in _SKIP_BYTES:
                total.bytes += out_bytes + self._operand_bytes(op, sym)
        self._memo[key] = total
        return total

    def _operand_bytes(self, op: _Op, sym: Dict[str, str]) -> float:
        tail = op.rest.split("),")[0]
        byts = 0.0
        for name in _OPERAND_RE.findall(tail):
            if name in sym:
                byts += _shape_elems_bytes(sym[name])[1]
        return byts

    def _largest_operand_bytes(self, op: _Op, sym: Dict[str, str]) -> float:
        tail = op.rest.split("),")[0]
        return max((_shape_elems_bytes(sym[name])[1]
                    for name in _OPERAND_RE.findall(tail) if name in sym),
                   default=0.0)

    def _second_operand_bytes(self, op: _Op, sym: Dict[str, str]) -> float:
        tail = op.rest.split("),")[0]
        sizes = sorted((_shape_elems_bytes(sym[name])[1]
                        for name in _OPERAND_RE.findall(tail) if name in sym),
                       reverse=True)
        return sizes[1] if len(sizes) > 1 else 0.0

    def _operands_below(self, op: _Op, sym: Dict[str, str],
                        cutoff: float) -> float:
        tail = op.rest.split("),")[0]
        return sum(b for b in (_shape_elems_bytes(sym[name])[1]
                               for name in _OPERAND_RE.findall(tail)
                               if name in sym) if b < cutoff)

    def _is_inplace_update(self, comp: str) -> bool:
        if comp not in self.computations:
            return False
        for sop in self.computations[comp]:
            if sop.opcode in ("dynamic-update-slice", "scatter"):
                return True
        return False

    def _operand_elems(self, op: _Op, sym: Dict[str, str]) -> float:
        tail = op.rest.split("),")[0]
        elems = 0.0
        for name in _OPERAND_RE.findall(tail):
            if name in sym:
                elems += _shape_elems_bytes(sym[name])[0]
        return elems

    def entry_cost(self) -> Cost:
        assert self.entry is not None, "no ENTRY computation found"
        return self.cost_of(self.entry)


def analyze(hlo_text: str, score_elems_threshold: Optional[float] = None) -> Dict:
    c = HloCostModel(hlo_text, score_elems_threshold).entry_cost()
    coll = dict(c.collectives)
    coll["total"] = sum(coll.values())
    return {"flops": c.flops, "bytes": c.bytes, "collectives": coll,
            "score_bytes": c.score_bytes, "unknown_loops": c.unknown_loops}
