"""Serving launcher: batched request loop over prefill + decode.

``python -m repro.launch.serve --arch granite-3-2b --smoke`` serves the
reduced config locally with a synthetic request stream. The same continuous
batching structure (prefill new requests, decode the active batch, retire
finished sequences) runs unmodified on the production mesh; it also backs the
CACTUSDB ``llm``-style black-box ML functions (examples/serve_llm_udf.py).
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.models import lm
from repro.models.config import ModelConfig


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class Server:
    """Batched greedy-decode server with a fixed batch of slots."""

    def __init__(self, cfg: ModelConfig, batch: int, max_len: int,
                 mesh=None, seed: int = 0):
        self.cfg = cfg
        self.batch = batch
        self.max_len = max_len
        self.params = lm.init_params(cfg, jax.random.PRNGKey(seed))
        self.decode_fn = jax.jit(lm.make_decode_step(cfg, mesh=mesh))
        self.cache = lm.init_cache(cfg, batch, max_len)
        self.active: List[Optional[Request]] = [None] * batch
        self.tokens = np.zeros((batch,), np.int32)
        self.free_slots = batch

    def admit(self, req: Request) -> bool:
        if self.free_slots == 0:
            return False
        for i, slot in enumerate(self.active):
            if slot is None:
                self.active[i] = req
                # prompt processed token-by-token (shared cache across slots
                # keeps this example simple; admit/step are smoke-tested on
                # the smoke config in tests/test_serving.py)
                self.tokens[i] = int(req.prompt[0])
                self.free_slots -= 1
                return True
        return False

    def step(self) -> int:
        logits, self.cache = self.decode_fn(self.params, self.cache,
                                            jnp.asarray(self.tokens))
        nxt = np.asarray(jnp.argmax(logits, axis=-1)).astype(np.int32)
        done = 0
        for i, req in enumerate(self.active):
            if req is None:
                continue
            pos = len(req.out)
            if pos + 1 < len(req.prompt):
                self.tokens[i] = int(req.prompt[pos + 1])  # teacher-forced
                req.out.append(int(nxt[i]))
            elif len(req.out) < len(req.prompt) + req.max_new:
                self.tokens[i] = int(nxt[i])
                req.out.append(int(nxt[i]))
            else:
                req.done = True
                self.active[i] = None
                self.free_slots += 1
                done += 1
        return done


def max_decode_steps(requests: List[Request]) -> int:
    """Upper bound on decode steps to serve ``requests``: while any request
    is pending or active, every step advances at least one active request by
    one token, and each request occupies at most prompt+max_new+1 steps
    (the +1 is the retirement step)."""
    return sum(len(r.prompt) + r.max_new + 1 for r in requests) + 1


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()
    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    rng = np.random.default_rng(0)
    server = Server(cfg, batch=args.batch, max_len=256)
    pending = [Request(rid=i,
                       prompt=rng.integers(0, cfg.vocab, rng.integers(4, 12)),
                       max_new=args.max_new)
               for i in range(args.requests)]
    t0 = time.perf_counter()
    finished = 0
    steps = 0
    step_bound = max_decode_steps(pending)
    while finished < args.requests:
        # only touch the admission path when a slot is actually free; a
        # refused request stays at the head of the queue
        while pending and server.free_slots > 0:
            if not server.admit(pending[0]):
                break
            pending.pop(0)
        finished += server.step()
        steps += 1
        if steps > step_bound:
            raise RuntimeError(
                f"serve loop did not converge in {step_bound} steps")
    dt = time.perf_counter() - t0
    print(f"served {args.requests} requests in {dt:.2f}s "
          f"({steps} decode steps, {args.requests * args.max_new / dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
