"""Training launcher: ``python -m repro.launch.train --arch granite-3-2b
--smoke`` runs the reduced config locally; on a TPU fleet the same entry
point builds the production mesh and runs the full config.

Sets the XLA latency-hiding-scheduler flags that overlap collectives with
per-shard GEMMs (compute/comm overlap — DESIGN.md Sec. 7)."""
import os

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = (
        "--xla_tpu_enable_latency_hiding_scheduler=true "
        if os.environ.get("REPRO_TPU") else "")

import argparse  # noqa: E402

from repro.configs import get_config, get_smoke_config  # noqa: E402
from repro.train.loop import train  # noqa: E402
from repro.train.stragglers import PreemptionGuard  # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    guard = PreemptionGuard()

    def hook(step, m):
        if step % 10 == 0:
            print(f"step {step:5d} loss {m['loss']:.4f} {m['dt']*1e3:.0f} ms",
                  flush=True)

    res = train(cfg, steps=args.steps, batch=args.batch, seq=args.seq,
                ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                microbatches=args.microbatches, lr=args.lr, guard=guard,
                hook=hook)
    print(f"done: step={res.step} first_loss={res.losses[0]:.4f} "
          f"last_loss={res.losses[-1]:.4f} resumed_from={res.resumed_from}")


if __name__ == "__main__":
    main()
