"""Production mesh builders — re-exported from the canonical mesh module.

All mesh helpers (production/host builders, the 1-D data mesh of the
sharded execution path, and the PartSpec partition arithmetic) live in
``repro.core.mesh``; this module survives as a compatibility shim for the
launch stack. Builders are functions, never module-level constants — the
dry-run must set XLA_FLAGS before any jax device state is touched.
"""
from __future__ import annotations

from repro.core.mesh import make_host_mesh, make_production_mesh

__all__ = ["make_host_mesh", "make_production_mesh"]
