"""Production mesh builders (functions, never module-level constants — the
dry-run must set XLA_FLAGS before any jax device state is touched)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 256 chips (16 data x 16 model). Multi-pod: 2 x 256."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(data: int | None = None, model: int = 1):
    """Small mesh over the locally visible devices (tests / CPU runs)."""
    n = jax.device_count()
    data = data if data is not None else max(n // model, 1)
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
