"""Cell builders for the dry-run: (arch x input-shape x mesh) -> abstract
inputs (ShapeDtypeStructs with shardings, no allocation) + the step function.

Shapes (assignment):
  train_4k    — seq 4096,  global batch 256  (train_step)
  prefill_32k — seq 32768, batch 32          (prefill -> logits + cache)
  decode_32k  — cache 32768, batch 128       (decode_step, one token)
  long_500k   — cache 524288, batch 1        (decode_step; sub-quadratic or
                compressed-latent archs only; skips documented in dryrun)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import lm, sharding
from repro.models.config import ModelConfig
from repro.train.optim import AdamW, AdamWState

SHAPES = {
    "train_4k": dict(seq=4096, batch=256, kind="train"),
    "prefill_32k": dict(seq=32768, batch=32, kind="prefill"),
    "decode_32k": dict(seq=32768, batch=128, kind="decode"),
    "long_500k": dict(seq=524288, batch=1, kind="decode"),
}

# microbatch counts chosen so activations fit 16GB HBM (see DESIGN.md)
MICROBATCHES = {
    "granite-moe-1b-a400m": 2, "deepseek-v2-236b": 32, "xlstm-1.3b": 4,
    "nemotron-4-15b": 8, "stablelm-12b": 8, "granite-3-2b": 2,
    "deepseek-67b": 16, "seamless-m4t-medium": 2, "zamba2-1.2b": 4,
    "qwen2-vl-72b": 16,
}


def long_context_applicability(cfg: ModelConfig) -> Tuple[bool, str]:
    if cfg.subquadratic:
        return True, "sub-quadratic (SSM/hybrid) — constant or S-sharded state"
    if cfg.attn == "mla":
        return True, ("beyond-spec extra: MLA's compressed latent cache makes "
                      "a 500k context practical")
    return False, ("skipped: pure full-attention arch — a 500k dense-KV decode "
                   "presupposes an infeasible 500k quadratic prefill "
                   "(DESIGN.md Sec. 5 shape policy)")


def _ns(mesh, spec):
    return NamedSharding(mesh, spec)


def _abs(shape, dtype, mesh, spec):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=_ns(mesh, spec))


def abstract_model_state(cfg: ModelConfig, mesh, with_opt: bool, opt=None):
    shapes = lm.abstract_params(cfg)
    pspecs = sharding.param_pspecs(cfg, lm.param_shapes(cfg), mesh)
    params = sharding.to_shape_dtype(shapes, mesh, pspecs)
    if not with_opt:
        return params, None, pspecs
    opt = opt or AdamW()
    opt_shapes = jax.eval_shape(opt.init, shapes)
    opt_specs = AdamWState(step=P(), mu=pspecs, nu=pspecs)
    opt_state = jax.tree.map(
        lambda x, s: jax.ShapeDtypeStruct(x.shape, x.dtype,
                                          sharding=_ns(mesh, s)),
        opt_shapes, opt_specs,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    return params, opt_state, pspecs


@dataclasses.dataclass
class Cell:
    fn: Callable
    args: Tuple
    static_descr: str
    out_shardings: Any = None


def build_cell(cfg: ModelConfig, shape_name: str, mesh,
               microbatches: Optional[int] = None) -> Cell:
    info = SHAPES[shape_name]
    seq, batch = info["seq"], info["batch"]
    bspec = sharding.batch_spec(mesh, batch)
    b_ax = bspec[0] if len(bspec) else None

    if info["kind"] == "train":
        # bf16 optimizer moments for the 100B+ models (§Perf iteration C3)
        moment_dtype = "bfloat16" if cfg.param_count() > 1e11 else "float32"
        opt = AdamW(moment_dtype=moment_dtype)
        params, opt_state, pspecs = abstract_model_state(
            cfg, mesh, True, opt=opt)
        mb = microbatches or MICROBATCHES.get(cfg.name, 4)
        step = lm.make_train_step(cfg, opt, microbatches=mb, mesh=mesh)
        batch_args: Dict[str, Any] = {
            "tokens": _abs((batch, seq), jnp.int32, mesh, bspec),
            "labels": _abs((batch, seq), jnp.int32, mesh, bspec),
        }
        if cfg.kind == "encdec":
            batch_args["enc_embeds"] = _abs((batch, seq, cfg.d_model),
                                            jnp.bfloat16, mesh,
                                            P(b_ax, None, None))
        if cfg.attn == "mrope":
            batch_args["pos3"] = _abs((3, batch, seq), jnp.int32, mesh,
                                      P(None, b_ax, None))
        out_shardings = (
            jax.tree.map(lambda s: _ns(mesh, s), pspecs),
            AdamWState(step=_ns(mesh, P()),
                       mu=jax.tree.map(lambda s: _ns(mesh, s), pspecs),
                       nu=jax.tree.map(lambda s: _ns(mesh, s), pspecs)),
            None,
        )
        return Cell(fn=step, args=(params, opt_state, batch_args),
                    static_descr=f"train mb={mb}", out_shardings=out_shardings)

    if info["kind"] == "prefill":
        # serving is TP-only when the params fit one model-parallel group:
        # no optimizer states, and dropping the data-axis FSDP sharding
        # sidesteps GSPMD's involuntary full rematerialization on FSDP
        # contractions (§Perf iteration A3'; refined in A5 — deepseek-v2's
        # 236B params exceed TP-only HBM, so it keeps FSDP sharding)
        if cfg.param_count() * 2 / mesh.shape["model"] < 10e9:
            cfg = dataclasses.replace(cfg, fsdp=False)
        params, _, pspecs = abstract_model_state(cfg, mesh, False)
        tokens = _abs((batch, seq), jnp.int32, mesh, bspec)
        extra = {}
        if cfg.kind == "encdec":
            extra["enc_embeds"] = _abs((batch, seq, cfg.d_model), jnp.bfloat16,
                                       mesh, P(b_ax, None, None))
        if cfg.attn == "mrope":
            extra["pos3"] = _abs((3, batch, seq), jnp.int32, mesh,
                                 P(None, b_ax, None))

        names = list(extra.keys())

        def step(params, tokens, *extras):
            kw = dict(zip(names, extras))
            return lm.prefill(params, cfg, tokens, max_len=seq, mesh=mesh, **kw)

        return Cell(fn=step, args=(params, tokens) + tuple(extra.values()),
                    static_descr="prefill")

    # decode — keep the param sharding as-is (decode is bandwidth-bound on
    # the cache regardless; TP-only params regressed HBM fit on the 60B+
    # models — §Perf iteration A5)
    params, _, pspecs = abstract_model_state(cfg, mesh, False)
    cache_shapes = jax.eval_shape(
        lambda: lm.init_cache(cfg, batch, seq,
                              enc_len=min(seq, 4096) if cfg.kind == "encdec" else 0))
    cspecs = sharding.cache_pspecs(cfg, cache_shapes, mesh, batch)
    cache = {k: jax.ShapeDtypeStruct(v.shape, v.dtype,
                                     sharding=_ns(mesh, cspecs[k]))
             for k, v in cache_shapes.items()}
    token = _abs((batch,), jnp.int32, mesh, P(b_ax))
    step = lm.make_decode_step(cfg, mesh=mesh)
    out_shardings = (None, {k: _ns(mesh, cspecs[k]) for k in cache})
    return Cell(fn=step, args=(params, cache, token),
                static_descr="decode", out_shardings=out_shardings)
