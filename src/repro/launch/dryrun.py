import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell on the
production mesh with 512 placeholder host devices.

For each cell we record:
  - compile success + wall time
  - cost_analysis flops / bytes accessed
  - collective bytes by kind (parsed from optimized HLO)
  - per-device memory (memory_analysis when available, else argument/output
    byte accounting)
  - MODEL_FLOPS = 6·N(_active)·D and the useful-compute ratio
  - the three roofline terms against TPU v5e (197 TF bf16, 819 GB/s HBM,
    ~50 GB/s/link ICI)

Usage:
  python -m repro.launch.dryrun --arch granite-3-2b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both --out experiments/dryrun
"""
import argparse          # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

import jax               # noqa: E402

from repro.configs import ARCHS, get_config               # noqa: E402
from repro.launch import hlo_stats                         # noqa: E402
from repro.launch.mesh import make_production_mesh         # noqa: E402
from repro.launch.specs import (SHAPES, build_cell,        # noqa: E402
                                long_context_applicability)

PEAK_FLOPS = 197e12     # bf16 per chip
HBM_BW = 819e9          # bytes/s per chip
ICI_BW = 50e9           # bytes/s per link


def input_specs(arch: str, shape_name: str, mesh):
    """Public helper (assignment API): ShapeDtypeStruct stand-ins for every
    model input of the given cell."""
    cfg = get_config(arch)
    return build_cell(cfg, shape_name, mesh).args


def run_cell(arch: str, shape_name: str, multi_pod: bool) -> dict:
    cfg = get_config(arch)
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16"}
    if shape_name == "long_500k":
        ok, why = long_context_applicability(cfg)
        rec["long_context_note"] = why
        if not ok:
            rec["status"] = "skipped"
            return rec
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        n_chips = mesh.size
        cell = build_cell(cfg, shape_name, mesh)
        t0 = time.time()
        # donate state buffers (params/opt at train, cache at decode) — the
        # production aliasing that keeps double-buffering off the HBM budget
        donate = ()
        if shape_name == "train_4k":
            donate = (0, 1)
        elif SHAPES[shape_name]["kind"] == "decode":
            donate = (1,)
        jitted = jax.jit(cell.fn, out_shardings=cell.out_shardings,
                         donate_argnums=donate) \
            if cell.out_shardings is not None else jax.jit(
                cell.fn, donate_argnums=donate)
        with mesh:
            lowered = jitted.lower(*cell.args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        rec.update(status="ok", lower_s=round(t_lower, 1),
                   compile_s=round(t_compile, 1), chips=n_chips,
                   descr=cell.static_descr)
        # --- cost analysis -------------------------------------------------
        # XLA's cost_analysis counts while bodies once (kept for reference);
        # hlo_cost multiplies loop trip counts (see launch/hlo_cost.py).
        ca = compiled.cost_analysis()
        if isinstance(ca, list):
            ca = ca[0]
        rec["xla_flops_loop_unaware"] = float(ca.get("flops", 0.0))
        hlo = compiled.as_text()
        from repro.launch import hlo_cost
        # score-tile threshold: any >=4-D f32 tensor at least the size of one
        # local attention-score tile (S_local x kv-chunk) — these stay in
        # VMEM under the Pallas flash kernels (kernels/flash_attention)
        seq = SHAPES[shape_name]["seq"]
        thresh = (seq / 16) * 512
        hc = hlo_cost.analyze(hlo, score_elems_threshold=thresh)
        flops = hc["flops"]
        bytes_acc = hc["bytes"]
        rec["hlo_flops"] = flops
        rec["hlo_bytes"] = bytes_acc
        rec["score_bytes"] = hc["score_bytes"]
        rec["unknown_loops"] = hc["unknown_loops"]
        # --- memory analysis ----------------------------------------------
        try:
            ma = compiled.memory_analysis()
            if ma is not None:
                rec["memory"] = {
                    "argument_bytes": int(getattr(ma, "argument_size_in_bytes", 0)),
                    "output_bytes": int(getattr(ma, "output_size_in_bytes", 0)),
                    "temp_bytes": int(getattr(ma, "temp_size_in_bytes", 0)),
                    "generated_code_bytes": int(getattr(ma, "generated_code_size_in_bytes", 0)),
                }
                tot = (rec["memory"]["argument_bytes"]
                       + rec["memory"]["temp_bytes"])
                rec["memory"]["per_device_total_gb"] = round(tot / 1e9, 3)
        except Exception as e:  # pragma: no cover
            rec["memory_error"] = str(e)
        # --- collective traffic (loop-multiplied) ---------------------------
        rec["collectives"] = {k: float(v) for k, v in hc["collectives"].items()}
        rec["collectives"].setdefault("total", 0.0)
        # --- roofline terms -------------------------------------------------
        # cost_analysis / memory_analysis / HLO shapes are PER-DEVICE (the
        # compiled module is the per-device SPMD program — verified against a
        # hand-counted sharded matmul).
        tokens = _tokens(shape_name)
        n_active = cfg.active_param_count()
        mult = 6.0 if shape_name == "train_4k" else 2.0
        model_flops = mult * n_active * tokens          # global useful FLOPs
        per_dev_model = model_flops / n_chips
        rec["model_flops"] = model_flops
        rec["useful_ratio"] = round(per_dev_model / flops, 4) if flops else None
        coll = rec["collectives"]["total"]
        rec["roofline"] = {
            "compute_s": flops / PEAK_FLOPS,
            "memory_s": bytes_acc / HBM_BW,
            "collective_s": coll / ICI_BW,
        }
        dom = max(rec["roofline"], key=rec["roofline"].get)
        rec["bottleneck"] = dom
        rt = rec["roofline"]
        denom = max(rt["compute_s"], rt["memory_s"], rt["collective_s"], 1e-30)
        rec["roofline_fraction"] = round(
            (per_dev_model / PEAK_FLOPS) / denom, 4)
        # kernel-deployed roofline: score tiles VMEM-resident under the
        # (implemented, oracle-validated) Pallas flash kernels
        mem_adj = (bytes_acc - hc["score_bytes"]) / HBM_BW
        rec["roofline_flash"] = dict(rt, memory_s=mem_adj)
        denom_adj = max(rt["compute_s"], mem_adj, rt["collective_s"], 1e-30)
        rec["bottleneck_flash"] = max(rec["roofline_flash"],
                                      key=rec["roofline_flash"].get)
        rec["roofline_fraction_flash"] = round(
            (per_dev_model / PEAK_FLOPS) / denom_adj, 4)
    except Exception as e:
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-3000:]
    return rec


def _tokens(shape_name: str) -> float:
    info = SHAPES[shape_name]
    if info["kind"] == "train":
        return info["seq"] * info["batch"]
    if info["kind"] == "prefill":
        return info["seq"] * info["batch"]
    return info["batch"]  # decode: one new token per sequence


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default=None)
    ap.add_argument("--shape", choices=sorted(SHAPES), default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    archs = sorted(ARCHS) if args.all or args.arch is None else [args.arch]
    shapes = sorted(SHAPES) if args.all or args.shape is None else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    os.makedirs(args.out, exist_ok=True)
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}_{shape}_{'multi' if mp else 'single'}"
                rec = run_cell(arch, shape, mp)
                path = os.path.join(args.out, tag + ".json")
                with open(path, "w") as f:
                    json.dump(rec, f, indent=2)
                status = rec.get("status")
                extra = ""
                if status == "ok":
                    extra = (f" flops={rec['hlo_flops']:.3e}"
                             f" coll={rec['collectives']['total']:.3e}B"
                             f" bottleneck={rec['bottleneck']}"
                             f" frac={rec['roofline_fraction']}")
                    if "memory" in rec:
                        extra += f" mem/dev={rec['memory']['per_device_total_gb']}GB"
                elif status == "error":
                    extra = " " + rec["error"][:200]
                print(f"[{status:7s}] {tag}{extra}", flush=True)


if __name__ == "__main__":
    main()
