"""Parse collective-communication bytes out of compiled HLO text.

cost_analysis() gives FLOPs and HBM bytes but not collective traffic; we sum
the result-shape bytes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute op in the optimized HLO (paper-of-record
approach for the roofline's collective term).
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(bf16|f64|f32|f16|f8e4m3fn|f8e5m2|s64|u64|s32|u32|"
                       r"s16|u16|s8|u8|pred|c64|c128)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|[\w\[\],{}\/ ]+?)\s+"
    r"(all-reduce-start|all-reduce|all-gather-start|all-gather|"
    r"reduce-scatter|all-to-all|collective-permute-start|collective-permute)"
    r"[\s(]", re.M)


def _shape_bytes(sig: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(sig):
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Bytes moved per collective kind (result-shape bytes, summed)."""
    out: Dict[str, int] = defaultdict(int)
    for m in _OP_RE.finditer(hlo_text):
        sig, kind = m.group(1), m.group(2)
        kind = kind.replace("-start", "")
        out[kind] += _shape_bytes(sig)
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return dict(out)


def count_ops(hlo_text: str, opname: str) -> int:
    return len(re.findall(rf"\b{re.escape(opname)}\(", hlo_text))
