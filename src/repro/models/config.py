"""Model configuration for the assigned architectures."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int
    n_shared: int = 0
    d_shared: int = 0
    capacity_factor: float = 1.25
    dense_layers: Tuple[int, ...] = ()  # layer indices using a dense FFN
    d_dense: int = 0


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora: int = 512
    q_lora: int = 1536
    rope_dim: int = 64
    nope_dim: int = 128
    v_dim: int = 128


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    kind: str                   # dense | moe | xlstm | hybrid | encdec
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    act: str = "swiglu"         # swiglu | squared_relu | gelu
    attn: str = "gqa"           # gqa | mla | mrope
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    # ssm / hybrid
    ssm_state: int = 64
    ssm_expand: int = 2
    conv_width: int = 4
    attn_every: int = 0         # zamba2: shared attention every k layers
    slstm_every: int = 0        # xlstm: sLSTM block every k layers
    # enc-dec
    enc_layers: int = 0
    # numerics / scale
    rope_theta: float = 1e4
    dtype: str = "bfloat16"
    remat: bool = True
    loss_chunk: int = 512       # CE computed in sequence chunks
    # sharding
    fsdp: bool = False          # additionally shard params over the data axis
    # sub-quadratic? (decides long_500k applicability)
    subquadratic: bool = False
    # notes for DESIGN.md / dry-run report
    source: str = ""

    @property
    def hd(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        return self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 so the embedding shards evenly
        over the model axis (MaxText-style). Logits beyond ``vocab`` are
        masked in the loss."""
        return -(-self.vocab // 256) * 256

    def param_count(self) -> float:
        """Analytic parameter count (for 6ND MODEL_FLOPS)."""
        D, L, V = self.d_model, self.n_layers, self.vocab
        total = V * D  # embedding (tied head)
        if self.kind == "encdec":
            total += V * D  # decoder side embeds output proj
        per_layer = 0.0
        hd = self.hd
        if self.kind in ("dense", "moe", "encdec"):
            if self.attn == "mla":
                m = self.mla
                qk = m.nope_dim + m.rope_dim
                per_layer += D * m.q_lora + m.q_lora * self.n_heads * qk
                per_layer += D * (m.kv_lora + m.rope_dim)
                per_layer += m.kv_lora * self.n_heads * (m.nope_dim + m.v_dim)
                per_layer += self.n_heads * m.v_dim * D
            else:
                per_layer += D * self.n_heads * hd        # q
                per_layer += 2 * D * self.n_kv_heads * hd  # k, v
                per_layer += self.n_heads * hd * D         # o
            if self.moe is not None:
                mo = self.moe
                per_layer += D * mo.n_experts               # router
                mats = 3 if self.act == "swiglu" else 2
                per_layer += mo.n_experts * mats * D * mo.d_expert
                per_layer += mo.n_shared * mats * D * mo.d_shared
            else:
                mats = 3 if self.act == "swiglu" else 2
                per_layer += mats * D * self.d_ff
            total += L * per_layer
            if self.kind == "encdec":
                # encoder layers + decoder cross-attention
                enc = (2 * D * self.n_heads * hd + 2 * D * self.n_kv_heads * hd
                       + 2 * D * self.d_ff)
                total += self.enc_layers * enc
                total += L * 4 * D * self.n_heads * hd  # cross-attn q,k,v,o
        elif self.kind == "xlstm":
            d_in = self.ssm_expand * D
            # mLSTM blocks: q,k,v,o-gate in_projs + out
            total += L * (4 * D * d_in + d_in * D + 2 * D * self.n_heads)
        elif self.kind == "hybrid":
            d_in = self.ssm_expand * D
            per_m = (D * d_in * 2 + D * 2 * self.ssm_state + D * self.n_heads
                     + d_in * D)
            total += L * per_m
            n_attn = L // max(self.attn_every, 1)
            shared = (2 * D * self.n_heads * hd + 2 * D * self.n_kv_heads * hd
                      + 2 * D * self.d_ff)
            total += shared  # ONE shared block (zamba2's point)
        return float(total)

    def active_param_count(self) -> float:
        """Active params per token (MoE-aware) for 6·N_active·D FLOPs."""
        if self.moe is None:
            return self.param_count()
        D, L = self.d_model, self.n_layers
        mo = self.moe
        mats = 3 if self.act == "swiglu" else 2
        full_routed = L * mo.n_experts * mats * D * mo.d_expert
        active_routed = L * mo.top_k * mats * D * mo.d_expert
        return self.param_count() - full_routed + active_routed
