"""Model zoo for the 10 assigned architectures.

config.py   — ModelConfig dataclass (family knobs: GQA/MLA/M-RoPE attention,
              MoE, xLSTM, Mamba2-hybrid, enc-dec)
layers.py   — norms, rotary (incl. M-RoPE), attention (chunked-flash jnp +
              Pallas dispatch, shard_map S-sharded flash decode), MLP, MoE
ssm.py      — shared chunked gated-linear-attention core (SSD duality),
              Mamba2 block, mLSTM, sLSTM
lm.py       — init / train_step loss / prefill / decode for every family
sharding.py — PartitionSpec trees for the production mesh
"""
from repro.models.config import ModelConfig, MoEConfig, MLAConfig

__all__ = ["ModelConfig", "MoEConfig", "MLAConfig"]
