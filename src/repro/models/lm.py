"""Model zoo core: parameter init, forward, loss, decode for all families.

Layers are stacked on a leading L axis and scanned (jax.lax.scan) so HLO size
is O(1) in depth; hybrid archs (zamba2 / xlstm) run segmented scans with the
shared/periodic blocks interleaved as static python segments. Cross-entropy
is computed in sequence chunks to bound the logits working set.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L
from repro.models import ssm
from repro.models.config import ModelConfig


def _dt(cfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# ===========================================================================
# parameter initialization
# ===========================================================================

def _dense_block_shapes(cfg: ModelConfig, n_layers: int) -> Dict[str, Tuple]:
    D, hd = cfg.d_model, cfg.hd
    s: Dict[str, Tuple] = {
        "ln1": (n_layers, D), "ln2": (n_layers, D),
    }
    if cfg.attn == "mla":
        m = cfg.mla
        qk = m.nope_dim + m.rope_dim
        s.update({
            "wq_a": (n_layers, D, m.q_lora), "q_ln": (n_layers, m.q_lora),
            "wq_b": (n_layers, m.q_lora, cfg.n_heads * qk),
            "wkv_a": (n_layers, D, m.kv_lora + m.rope_dim),
            "kv_ln": (n_layers, m.kv_lora),
            "wkv_b": (n_layers, m.kv_lora, cfg.n_heads * (m.nope_dim + m.v_dim)),
            "wo": (n_layers, cfg.n_heads * m.v_dim, D),
        })
    else:
        s.update({
            "wq": (n_layers, D, cfg.n_heads * hd),
            "wk": (n_layers, D, cfg.n_kv_heads * hd),
            "wv": (n_layers, D, cfg.n_kv_heads * hd),
            "wo": (n_layers, cfg.n_heads * hd, D),
        })
    if cfg.moe is not None:
        mo = cfg.moe
        s["router"] = (n_layers, D, mo.n_experts)
        s["e_in"] = (n_layers, mo.n_experts, D, mo.d_expert)
        s["e_out"] = (n_layers, mo.n_experts, mo.d_expert, D)
        if cfg.act == "swiglu":
            s["e_gate"] = (n_layers, mo.n_experts, D, mo.d_expert)
        if mo.n_shared:
            s["sh_in"] = (n_layers, D, mo.n_shared * mo.d_shared)
            s["sh_out"] = (n_layers, mo.n_shared * mo.d_shared, D)
            if cfg.act == "swiglu":
                s["sh_gate"] = (n_layers, D, mo.n_shared * mo.d_shared)
    else:
        s["w_in"] = (n_layers, D, cfg.d_ff)
        s["w_out"] = (n_layers, cfg.d_ff, D)
        if cfg.act == "swiglu":
            s["w_gate"] = (n_layers, D, cfg.d_ff)
    return s


def _mamba_shapes(cfg, n_layers):
    D = cfg.d_model
    d_in = cfg.ssm_expand * D
    return {
        "ln": (n_layers, D),
        "w_in": (n_layers, D, d_in), "w_z": (n_layers, D, d_in),
        "w_bc": (n_layers, D, 2 * cfg.ssm_state),
        "w_dt": (n_layers, D, cfg.n_heads), "dt_bias": (n_layers, cfg.n_heads),
        "conv_w": (n_layers, cfg.conv_width, d_in),
        "A_log": (n_layers, cfg.n_heads), "D_skip": (n_layers, cfg.n_heads),
        "w_out": (n_layers, d_in, D),
    }


def _mlstm_shapes(cfg, n_layers):
    D = cfg.d_model
    d_in = cfg.ssm_expand * D
    return {
        "ln": (n_layers, D),
        "w_q": (n_layers, D, d_in), "w_k": (n_layers, D, d_in),
        "w_v": (n_layers, D, d_in), "w_o": (n_layers, D, d_in),
        "w_gates": (n_layers, D, 2 * cfg.n_heads),
        "w_out": (n_layers, d_in, D),
    }


def _slstm_shapes(cfg, n_layers):
    D = cfg.d_model
    return {"ln": (n_layers, D), "w_gates": (n_layers, D, 4 * D),
            "r_gates": (n_layers, D, 4 * D), "w_out": (n_layers, D, D)}


def param_shapes(cfg: ModelConfig) -> Dict[str, Any]:
    D, V = cfg.d_model, cfg.padded_vocab
    tree: Dict[str, Any] = {"embed": (V, D), "final_norm": (D,)}
    if cfg.kind in ("dense", "moe"):
        tree["blocks"] = _dense_block_shapes(cfg, cfg.n_layers)
    elif cfg.kind == "encdec":
        tree["enc_blocks"] = _dense_block_shapes(cfg, cfg.enc_layers)
        tree["blocks"] = _dense_block_shapes(cfg, cfg.n_layers)
        cross = {
            "ln_x": (cfg.n_layers, D),
            "xq": (cfg.n_layers, D, cfg.n_heads * cfg.hd),
            "xk": (cfg.n_layers, D, cfg.n_kv_heads * cfg.hd),
            "xv": (cfg.n_layers, D, cfg.n_kv_heads * cfg.hd),
            "xo": (cfg.n_layers, cfg.n_heads * cfg.hd, D),
        }
        tree["cross"] = cross
        tree["enc_norm"] = (D,)
    elif cfg.kind == "xlstm":
        seg = cfg.slstm_every
        n_seg = cfg.n_layers // seg
        tree["mlstm"] = _mlstm_shapes(cfg, n_seg * (seg - 1))
        tree["slstm"] = _slstm_shapes(cfg, n_seg)
    elif cfg.kind == "hybrid":
        tree["mamba"] = _mamba_shapes(cfg, cfg.n_layers)
        # ONE shared attention+mlp block (zamba2)
        tree["shared_attn"] = {
            "ln1": (D,), "ln2": (D,),
            "wq": (D, cfg.n_heads * cfg.hd), "wk": (D, cfg.n_kv_heads * cfg.hd),
            "wv": (D, cfg.n_kv_heads * cfg.hd), "wo": (cfg.n_heads * cfg.hd, D),
            "w_gate": (D, cfg.d_ff), "w_in": (D, cfg.d_ff), "w_out": (cfg.d_ff, D),
        }
    else:
        raise ValueError(cfg.kind)
    return tree


def init_params(cfg: ModelConfig, key: jax.Array):
    shapes = param_shapes(cfg)
    dt = _dt(cfg)
    flat, treedef = jax.tree.flatten(shapes, is_leaf=lambda x: isinstance(x, tuple))
    keys = jax.random.split(key, len(flat))

    def mk(shape, k):
        if len(shape) == 1 or shape[-1] == shape[-2] == 0:
            return jnp.ones(shape, dt)  # norms
        fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
        return (jax.random.normal(k, shape, jnp.float32)
                / np.sqrt(max(fan_in, 1))).astype(dt)

    leaves = [mk(s, k) for s, k in zip(flat, keys)]
    params = jax.tree.unflatten(treedef, leaves)
    # norm-like vectors should be ones; biases zero
    def fix(path, x):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        if name in ("ln", "ln1", "ln2", "ln_x", "final_norm", "enc_norm",
                    "q_ln", "kv_ln"):
            return jnp.ones_like(x)
        if name in ("dt_bias",):
            return jnp.full_like(x, -2.0)
        if name == "A_log":
            return jnp.zeros_like(x)
        if name == "D_skip":
            return jnp.ones_like(x)
        return x

    return jax.tree_util.tree_map_with_path(fix, params)


def abstract_params(cfg: ModelConfig, key=None):
    """ShapeDtypeStructs only — used by the dry-run (no allocation)."""
    return jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))


# ===========================================================================
# forward — dense / moe / mla / mrope decoder blocks
# ===========================================================================

_FSDP_GATHER_SPECS = {
    # unshard-at-use layouts: gathered over `data`, still `model`-sharded.
    # Without these constraints GSPMD lowers the FSDP matmuls as
    # partial-contraction + full-activation f32 all-reduces (15.5 GB/layer on
    # qwen2-72b) instead of 54 MB/layer weight gathers — §Perf iteration A3.
    "wq": ("model",), "wk": (None,), "wv": (None,), "wo": ("model", None),
    "w_gate": ("model",), "w_in": ("model",), "w_out": ("model", None),
    "wq_a": (None,), "wq_b": ("model",), "wkv_a": (None,),
    "wkv_b": ("model",), "xq": ("model",), "xk": (None,), "xv": (None,),
    "xo": ("model", None),
}


def _gather_fsdp(blk, cfg: ModelConfig, mesh):
    """FSDP unshard-at-use: constrain weight slices to their gathered layout
    right before the matmuls."""
    if mesh is None or not cfg.fsdp:
        return blk
    from jax.sharding import NamedSharding, PartitionSpec as P
    out = dict(blk)
    for name, tail in _FSDP_GATHER_SPECS.items():
        w = out.get(name)
        if w is None or w.ndim != 2:
            continue
        spec = (P(None, tail[0]) if len(tail) == 1 else P(*tail))
        out[name] = jax.lax.with_sharding_constraint(
            w, NamedSharding(mesh, spec))
    return out


def _attn_prefill(x, blk, cfg: ModelConfig, positions, pos3=None,
                  kv_override=None, causal=True, with_kv=False):
    b, s, d = x.shape
    hd = cfg.hd
    q = (x @ blk["wq"]).reshape(b, s, cfg.n_heads, hd)
    src = x if kv_override is None else kv_override
    k = (src @ blk["wk"]).reshape(b, -1, cfg.n_kv_heads, hd)
    v = (src @ blk["wv"]).reshape(b, -1, cfg.n_kv_heads, hd)
    if cfg.attn == "mrope":
        q = L.apply_mrope(q, pos3, theta=cfg.rope_theta)
        k = L.apply_mrope(k, pos3, theta=cfg.rope_theta)
    elif kv_override is None:
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
    o = L.jnp_flash_attention(q, k, v, causal=causal)
    out = o.reshape(b, s, cfg.n_heads * hd) @ blk["wo"]
    if with_kv:
        return out, (k, v)
    return out


def _mla_prefill(x, blk, cfg: ModelConfig, positions):
    m = cfg.mla
    b, s, d = x.shape
    H = cfg.n_heads
    qk = m.nope_dim + m.rope_dim
    q = L.rms_norm(x @ blk["wq_a"], blk["q_ln"]) @ blk["wq_b"]
    q = q.reshape(b, s, H, qk)
    q_nope, q_pe = q[..., :m.nope_dim], q[..., m.nope_dim:]
    kv = x @ blk["wkv_a"]
    ckv = L.rms_norm(kv[..., :m.kv_lora], blk["kv_ln"])
    k_pe = kv[..., m.kv_lora:].reshape(b, s, 1, m.rope_dim)
    q_pe = L.apply_rope(q_pe, positions, cfg.rope_theta)
    k_pe = L.apply_rope(k_pe, positions, cfg.rope_theta)
    kvb = (ckv @ blk["wkv_b"]).reshape(b, s, H, m.nope_dim + m.v_dim)
    k_nope, v = kvb[..., :m.nope_dim], kvb[..., m.nope_dim:]
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_pe, (b, s, H, m.rope_dim))],
                        axis=-1)
    qq = jnp.concatenate([q_nope, q_pe], axis=-1)
    o = L.jnp_flash_attention(qq, k, v, causal=True, scale=qk ** -0.5)
    return o.reshape(b, s, H * m.v_dim) @ blk["wo"]


def _ffn(x, blk, cfg: ModelConfig, mesh=None):
    if cfg.moe is not None:
        b, s, d = x.shape
        flat = x.reshape(b * s, d)
        y = L.moe_block(flat, blk["router"], blk.get("e_gate"), blk["e_in"],
                        blk["e_out"], cfg, mesh=mesh)
        if cfg.moe.n_shared:
            y = y + L.mlp(flat, blk.get("sh_gate"), blk["sh_in"],
                          blk["sh_out"], cfg.act)
        return y.reshape(b, s, d)
    return L.mlp(x, blk.get("w_gate"), blk["w_in"], blk["w_out"], cfg.act)


def _decoder_block(x, blk, cfg: ModelConfig, positions, pos3, causal=True,
                   mesh=None):
    # (_gather_fsdp unshard-at-use was tried and REFUTED for train: forcing
    # the gather layout doubled collective traffic via transposed reshards
    # in backward — §Perf iteration A4; kept for reference, not applied)
    h = L.rms_norm(x, blk["ln1"])
    if cfg.attn == "mla":
        a = _mla_prefill(h, blk, cfg, positions)
    else:
        a = _attn_prefill(h, blk, cfg, positions, pos3, causal=causal)
    x = x + a
    h = L.rms_norm(x, blk["ln2"])
    return x + _ffn(h, blk, cfg, mesh)


def _scan_blocks(x, blocks, cfg, positions, pos3, causal=True, cross=None,
                 enc_h=None, mesh=None):
    def body(carry, layer):
        h = carry
        if cross is None:
            return _decoder_block(h, layer, cfg, positions, pos3,
                                  causal=causal, mesh=mesh), None
        blk, xblk = layer
        # self-attn -> cross-attn -> FFN (matches prefill/decode order)
        hh = L.rms_norm(h, blk["ln1"])
        h = h + _attn_prefill(hh, blk, cfg, positions, pos3, causal=causal)
        hh = L.rms_norm(h, xblk["ln_x"])
        b, s, d = hh.shape
        q = (hh @ xblk["xq"]).reshape(b, s, cfg.n_heads, cfg.hd)
        k = (enc_h @ xblk["xk"]).reshape(b, -1, cfg.n_kv_heads, cfg.hd)
        v = (enc_h @ xblk["xv"]).reshape(b, -1, cfg.n_kv_heads, cfg.hd)
        o = L.jnp_flash_attention(q, k, v, causal=False)
        h = h + o.reshape(b, s, cfg.n_heads * cfg.hd) @ xblk["xo"]
        hh = L.rms_norm(h, blk["ln2"])
        return h + _ffn(hh, blk, cfg, mesh), None

    fn = jax.checkpoint(body) if cfg.remat else body
    xs = blocks if cross is None else (blocks, cross)
    x, _ = jax.lax.scan(fn, x, xs)
    return x


def forward(params, cfg: ModelConfig, tokens, positions=None, pos3=None,
            enc_embeds=None, mesh=None):
    """Returns final hidden states [B, S, D].

    tokens: [B, S] int32 (decoder input). enc_embeds: [B, S_src, D] for
    enc-dec (the modality frontend stub's output)."""
    b, s = tokens.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    if cfg.attn == "mrope" and pos3 is None:
        pos3 = jnp.broadcast_to(positions[None], (3, b, s))
    x = params["embed"][tokens].astype(_dt(cfg))
    if cfg.kind in ("dense", "moe"):
        x = _scan_blocks(x, params["blocks"], cfg, positions, pos3, mesh=mesh)
    elif cfg.kind == "encdec":
        enc_pos = jnp.broadcast_to(jnp.arange(enc_embeds.shape[1])[None],
                                   enc_embeds.shape[:2])
        e = _scan_blocks(enc_embeds.astype(_dt(cfg)), params["enc_blocks"],
                         cfg, enc_pos, None, causal=False)
        e = L.rms_norm(e, params["enc_norm"])
        x = _scan_blocks(x, params["blocks"], cfg, positions, None,
                         causal=True, cross=params["cross"], enc_h=e,
                         mesh=mesh)
    elif cfg.kind == "xlstm":
        x = _xlstm_forward(x, params, cfg)
    elif cfg.kind == "hybrid":
        x = _hybrid_forward(x, params, cfg, positions)
    return L.rms_norm(x, params["final_norm"])


def _xlstm_forward(x, params, cfg):
    seg = cfg.slstm_every
    n_seg = cfg.n_layers // seg
    per = seg - 1
    m = params["mlstm"]

    def m_body(carry, layer):
        h = carry
        hh = L.rms_norm(h, layer["ln"])
        out, _ = ssm.mlstm_forward(hh, layer, cfg)
        return h + out, None

    m_fn = jax.checkpoint(m_body) if cfg.remat else m_body
    for si in range(n_seg):
        seg_params = jax.tree.map(lambda a: a[si * per:(si + 1) * per], m)
        x, _ = jax.lax.scan(m_fn, x, seg_params)
        sl = jax.tree.map(lambda a: a[si], params["slstm"])
        hh = L.rms_norm(x, sl["ln"])
        out, _ = ssm.slstm_forward(hh, sl, cfg)
        x = x + out
    return x


def _hybrid_forward(x, params, cfg, positions):
    mp = params["mamba"]
    sh = params["shared_attn"]

    def m_body(carry, layer):
        h = carry
        hh = L.rms_norm(h, layer["ln"])
        out, _ = ssm.mamba2_forward(hh, layer, cfg)
        return h + out, None

    m_fn = jax.checkpoint(m_body) if cfg.remat else m_body
    every = cfg.attn_every
    pos = 0
    while pos < cfg.n_layers:
        n = min(every, cfg.n_layers - pos)
        seg_params = jax.tree.map(lambda a: a[pos:pos + n], mp)
        x, _ = jax.lax.scan(m_fn, x, seg_params)
        pos += n
        if pos < cfg.n_layers or pos == cfg.n_layers:
            # shared attention block after each segment (zamba2)
            h = L.rms_norm(x, sh["ln1"])
            a = _attn_prefill(h, sh, cfg, positions, None, causal=True)
            x = x + a
            h = L.rms_norm(x, sh["ln2"])
            x = x + L.mlp(h, sh.get("w_gate"), sh["w_in"], sh["w_out"], "swiglu")
    return x


# ===========================================================================
# loss (chunked CE) + train step
# ===========================================================================

def loss_fn(params, cfg: ModelConfig, batch, mesh=None) -> jax.Array:
    tokens = batch["tokens"]
    labels = batch["labels"]
    h = forward(params, cfg, tokens, enc_embeds=batch.get("enc_embeds"),
                pos3=batch.get("pos3"), mesh=mesh)
    b, s, d = h.shape
    emb = params["embed"]
    chunk = min(cfg.loss_chunk, s)
    nc = s // chunk if s % chunk == 0 else -(-s // chunk)
    pad = nc * chunk - s
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    hc = h.reshape(b, nc, chunk, d).swapaxes(0, 1)
    lc = labels.reshape(b, nc, chunk).swapaxes(0, 1)

    vocab_iota = jnp.arange(cfg.padded_vocab)

    def ce(carry, inp):
        hh, ll = inp
        logits = (hh.astype(jnp.float32) @ emb.T.astype(jnp.float32))
        logits = jnp.where(vocab_iota[None, None, :] < cfg.vocab, logits, -1e30)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(ll, 0)[..., None], axis=-1)[..., 0]
        mask = (ll >= 0).astype(jnp.float32)
        return (carry[0] + jnp.sum((lse - gold) * mask),
                carry[1] + jnp.sum(mask)), None

    fn = jax.checkpoint(ce) if cfg.remat else ce
    (tot, cnt), _ = jax.lax.scan(fn, (0.0, 0.0), (hc, lc))
    return tot / jnp.maximum(cnt, 1.0)


def make_train_step(cfg: ModelConfig, optimizer, microbatches: int = 1,
                    accum_dtype=jnp.float32, mesh=None):
    """Train step with microbatched gradient accumulation (scan over
    microbatches keeps activation memory at 1/M of the global batch —
    required to fit the larger archs on 16GB v5e chips)."""

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            loss, grads = jax.value_and_grad(
                lambda p: loss_fn(p, cfg, batch, mesh=mesh))(params)
        else:
            def split(k, x):
                if k == "pos3":  # [3, B, S] — batch lives on axis 1
                    r = x.reshape((3, microbatches, x.shape[1] // microbatches)
                                  + x.shape[2:])
                    return jnp.swapaxes(r, 0, 1)
                return x.reshape((microbatches, x.shape[0] // microbatches)
                                 + x.shape[1:])

            mbatch = {k: split(k, v) for k, v in batch.items()}

            def acc_step(carry, mb):
                loss_acc, g_acc = carry
                l, g = jax.value_and_grad(
                    lambda p: loss_fn(p, cfg, mb, mesh=mesh))(params)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(accum_dtype), g_acc, g)
                return (loss_acc + l, g_acc), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, accum_dtype), params)
            (loss, grads), _ = jax.lax.scan(acc_step, (0.0, g0), mbatch)
            loss = loss / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, grads)
        params, opt_state = optimizer.update(grads, opt_state, params)
        return params, opt_state, {"loss": loss}

    return train_step


# ===========================================================================
# serving: cache init, prefill, decode
# ===========================================================================

def init_cache(cfg: ModelConfig, batch: int, max_len: int, enc_len: int = 0):
    dt = _dt(cfg)
    hd = cfg.hd
    if cfg.kind in ("dense", "moe") and cfg.attn != "mla":
        return {"k": jnp.zeros((cfg.n_layers, batch, max_len, cfg.n_kv_heads, hd), dt),
                "v": jnp.zeros((cfg.n_layers, batch, max_len, cfg.n_kv_heads, hd), dt),
                "len": jnp.zeros((), jnp.int32)}
    if cfg.attn == "mla":
        m = cfg.mla
        return {"ckv": jnp.zeros((cfg.n_layers, batch, max_len, m.kv_lora), dt),
                "kpe": jnp.zeros((cfg.n_layers, batch, max_len, m.rope_dim), dt),
                "len": jnp.zeros((), jnp.int32)}
    if cfg.kind == "xlstm":
        seg = cfg.slstm_every
        n_seg = cfg.n_layers // seg
        per = seg - 1
        d_in = cfg.ssm_expand * cfg.d_model
        dh = d_in // cfg.n_heads
        return {"mS": jnp.zeros((n_seg * per, batch, cfg.n_heads, dh, dh + 1),
                                jnp.float32),
                "sh": jnp.zeros((n_seg, batch, cfg.d_model), jnp.float32),
                "sc": jnp.zeros((n_seg, batch, cfg.d_model), jnp.float32),
                "sn": jnp.zeros((n_seg, batch, cfg.d_model), jnp.float32),
                "len": jnp.zeros((), jnp.int32)}
    if cfg.kind == "hybrid":
        d_in = cfg.ssm_expand * cfg.d_model
        dh = d_in // cfg.n_heads
        n_attn = -(-cfg.n_layers // cfg.attn_every)
        return {"conv": jnp.zeros((cfg.n_layers, batch, cfg.conv_width - 1, d_in), dt),
                "ssm": jnp.zeros((cfg.n_layers, batch, cfg.n_heads,
                                  cfg.ssm_state, dh), jnp.float32),
                "k": jnp.zeros((n_attn, batch, max_len, cfg.n_kv_heads, hd), dt),
                "v": jnp.zeros((n_attn, batch, max_len, cfg.n_kv_heads, hd), dt),
                "len": jnp.zeros((), jnp.int32)}
    if cfg.kind == "encdec":
        return {"k": jnp.zeros((cfg.n_layers, batch, max_len, cfg.n_kv_heads, hd), dt),
                "v": jnp.zeros((cfg.n_layers, batch, max_len, cfg.n_kv_heads, hd), dt),
                "enc_h": jnp.zeros((batch, enc_len, cfg.d_model), dt),
                "len": jnp.zeros((), jnp.int32)}
    raise ValueError(cfg.kind)


def _decode_attn(q, k_cache, v_cache, cache_len, mesh):
    """q: [B,H,hd]; caches [B,S,kv,hd]."""
    if mesh is not None:
        return L.sharded_decode_attention(q, k_cache, v_cache, cache_len, mesh)
    hd = q.shape[-1]
    b, h = q.shape[0], q.shape[1]
    hkv = k_cache.shape[2]
    qg = q.reshape(b, hkv, h // hkv, hd)
    kk = k_cache.swapaxes(1, 2)  # [B,kv,S,hd]
    vv = v_cache.swapaxes(1, 2)
    sc = jnp.einsum("bngd,bnsd->bngs", qg.astype(jnp.float32),
                    kk.astype(jnp.float32)) * hd ** -0.5
    cols = jnp.arange(k_cache.shape[1])
    sc = jnp.where(cols[None, None, None, :] < cache_len, sc, -1e30)
    w = jax.nn.softmax(sc, axis=-1)
    o = jnp.einsum("bngs,bnsd->bngd", w, vv.astype(jnp.float32))
    return o.reshape(b, h, hd).astype(q.dtype)


def make_decode_step(cfg: ModelConfig, mesh=None):
    """Returns decode_step(params, cache, token [B]) -> (logits [B,V], cache)."""

    def gqa_layer(x, blk, k_cache, v_cache, clen, positions):
        b = x.shape[0]
        hd = cfg.hd
        h = L.rms_norm(x, blk["ln1"])
        q = (h @ blk["wq"]).reshape(b, 1, cfg.n_heads, hd)
        k = (h @ blk["wk"]).reshape(b, 1, cfg.n_kv_heads, hd)
        v = (h @ blk["wv"]).reshape(b, 1, cfg.n_kv_heads, hd)
        if cfg.attn == "mrope":
            pos3 = jnp.broadcast_to(positions[None], (3, b, 1))
            q = L.apply_mrope(q, pos3, theta=cfg.rope_theta)
            k = L.apply_mrope(k, pos3, theta=cfg.rope_theta)
        else:
            q = L.apply_rope(q, positions, cfg.rope_theta)
            k = L.apply_rope(k, positions, cfg.rope_theta)
        k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k, clen, axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v, clen, axis=1)
        o = _decode_attn(q[:, 0], k_cache, v_cache, clen + 1, mesh)
        x = x + o.reshape(b, cfg.n_heads * hd) @ blk["wo"]
        h = L.rms_norm(x, blk["ln2"])
        return x + _ffn(h[:, None, :], blk, cfg, mesh)[:, 0], k_cache, v_cache

    def decode_step(params, cache, token, enc_h=None):
        b = token.shape[0]
        clen = cache["len"]
        positions = jnp.broadcast_to(clen[None, None], (b, 1))
        x = params["embed"][token].astype(_dt(cfg))          # [B, D]
        if cfg.kind in ("dense", "moe") and cfg.attn != "mla":
            def body(carry, layer):
                h, i = carry
                blk, kc, vc = layer
                h2, kc2, vc2 = gqa_layer(h, blk, kc, vc, clen, positions)
                return (h2, i + 1), (kc2, vc2)

            (x, _), (ks, vs) = jax.lax.scan(
                body, (x, 0), (params["blocks"], cache["k"], cache["v"]))
            cache = dict(cache, k=ks, v=vs, len=clen + 1)
        elif cfg.attn == "mla":
            x, cache = _mla_decode(params, cfg, cache, x, positions, mesh)
        elif cfg.kind == "xlstm":
            x, cache = _xlstm_decode(params, cfg, cache, x)
        elif cfg.kind == "hybrid":
            x, cache = _hybrid_decode(params, cfg, cache, x, positions, mesh)
        elif cfg.kind == "encdec":
            x, cache = _encdec_decode(params, cfg, cache, x, positions,
                                      enc_h if enc_h is not None else cache["enc_h"])
        x = L.rms_norm(x, params["final_norm"])
        logits = x.astype(jnp.float32) @ params["embed"].T.astype(jnp.float32)
        logits = jnp.where(jnp.arange(cfg.padded_vocab)[None, :] < cfg.vocab,
                           logits, -1e30)
        return logits, cache

    return decode_step


def _mla_decode(params, cfg, cache, x, positions, mesh):
    m = cfg.mla
    H = cfg.n_heads
    b = x.shape[0]
    clen = cache["len"]

    def body(carry, layer):
        h = carry
        blk, ckv_c, kpe_c = layer
        hh = L.rms_norm(h, blk["ln1"])
        q = L.rms_norm(hh @ blk["wq_a"], blk["q_ln"]) @ blk["wq_b"]
        q = q.reshape(b, H, m.nope_dim + m.rope_dim)
        q_nope, q_pe = q[..., :m.nope_dim], q[..., m.nope_dim:]
        q_pe = L.apply_rope(q_pe[:, None], positions, cfg.rope_theta)[:, 0]
        kv = hh @ blk["wkv_a"]
        ckv = L.rms_norm(kv[..., :m.kv_lora], blk["kv_ln"])
        kpe = L.apply_rope(kv[..., m.kv_lora:][:, None, None, :], positions,
                           cfg.rope_theta)[:, 0, 0]
        ckv_c = jax.lax.dynamic_update_slice_in_dim(ckv_c, ckv[:, None], clen, 1)
        kpe_c = jax.lax.dynamic_update_slice_in_dim(kpe_c, kpe[:, None], clen, 1)
        # absorbed attention: q_c = q_nope @ W_uk  -> latent space
        wkv_b = blk["wkv_b"].reshape(m.kv_lora, H, m.nope_dim + m.v_dim)
        w_uk = wkv_b[..., :m.nope_dim]                    # [kvlora, H, nope]
        w_uv = wkv_b[..., m.nope_dim:]                    # [kvlora, H, v]
        q_c = jnp.einsum("bhn,khn->bhk", q_nope.astype(jnp.float32),
                         w_uk.astype(jnp.float32))        # [B,H,kvlora]
        scale = (m.nope_dim + m.rope_dim) ** -0.5
        ctx = _mla_latent_attention(q_c, q_pe, ckv_c, kpe_c, clen + 1, scale,
                                    mesh)                  # [B,H,kvlora]
        o = jnp.einsum("bhk,khv->bhv", ctx, w_uv.astype(jnp.float32))
        o = o.reshape(b, H * m.v_dim).astype(h.dtype)
        h = h + o @ blk["wo"]
        hh = L.rms_norm(h, blk["ln2"])
        h = h + _ffn(hh[:, None, :], blk, cfg, mesh)[:, 0]
        return h, (ckv_c, kpe_c)

    x, (ckvs, kpes) = jax.lax.scan(body, x, (params["blocks"], cache["ckv"],
                                             cache["kpe"]))
    return x, dict(cache, ckv=ckvs, kpe=kpes, len=cache["len"] + 1)


def _mla_latent_attention(q_c, q_pe, ckv_c, kpe_c, valid_len, scale, mesh):
    """Scores over the latent cache; S axis optionally sharded over model."""

    def local(qc, qp, ck, kp, vl, offset):
        sc = (jnp.einsum("bhk,bsk->bhs", qc, ck.astype(jnp.float32))
              + jnp.einsum("bhr,bsr->bhs", qp.astype(jnp.float32),
                           kp.astype(jnp.float32))) * scale
        cols = jnp.arange(ck.shape[1]) + offset
        sc = jnp.where(cols[None, None, :] < vl, sc, -1e30)
        m = sc.max(-1)
        p = jnp.exp(sc - m[..., None])
        l = p.sum(-1)
        acc = jnp.einsum("bhs,bsk->bhk", p, ck.astype(jnp.float32))
        return acc, m, l

    if mesh is None:
        acc, m, l = local(q_c, q_pe, ckv_c, kpe_c, valid_len, 0)
        return acc / jnp.maximum(l, 1e-30)[..., None]

    from jax.sharding import PartitionSpec as P
    n_shards = mesh.shape["model"]
    s_loc = ckv_c.shape[1] // n_shards
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names) or None
    if batch_axes is not None:
        ways = 1
        for a in batch_axes:
            ways *= mesh.shape[a]
        if q_c.shape[0] % ways != 0:
            batch_axes = None  # e.g. batch=1 long-context decode

    def shard_fn(qc, qp, ck, kp, vl):
        idx = jax.lax.axis_index("model")
        acc, m, l = local(qc, qp, ck, kp, vl, idx * s_loc)
        m_all = jax.lax.pmax(m, "model")
        w = jnp.exp(m - m_all)
        num = jax.lax.psum(acc * w[..., None], "model")
        den = jax.lax.psum(l * w, "model")
        return num / jnp.maximum(den, 1e-30)[..., None]

    return jax.shard_map(
        shard_fn, mesh=mesh,
        in_specs=(P(batch_axes, None, None), P(batch_axes, None, None),
                  P(batch_axes, "model", None), P(batch_axes, "model", None),
                  P()),
        out_specs=P(batch_axes, None, None),
    )(q_c, q_pe, ckv_c, kpe_c, valid_len)


def _xlstm_decode(params, cfg, cache, x):
    seg = cfg.slstm_every
    n_seg = cfg.n_layers // seg
    per = seg - 1

    def m_body(carry, layer):
        h = carry
        blk, S = layer
        hh = L.rms_norm(h, blk["ln"])
        out, (S2,) = ssm.mlstm_forward(hh[:, None], blk, cfg, state=(S,),
                                       decode=True)
        return h + out[:, 0], S2

    x_cur = x
    mS = cache["mS"]
    new_mS = []
    for si in range(n_seg):
        seg_p = jax.tree.map(lambda a: a[si * per:(si + 1) * per], params["mlstm"])
        seg_S = mS[si * per:(si + 1) * per]
        x_cur, S_out = jax.lax.scan(m_body, x_cur, (seg_p, seg_S))
        new_mS.append(S_out)
        sl = jax.tree.map(lambda a: a[si], params["slstm"])
        hh = L.rms_norm(x_cur, sl["ln"])
        st = (cache["sh"][si], cache["sc"][si], cache["sn"][si])
        out, (h2, c2, n2) = ssm.slstm_forward(hh[:, None], sl, cfg, state=st,
                                              decode=True)
        x_cur = x_cur + out[:, 0]
        cache = dict(cache, sh=cache["sh"].at[si].set(h2),
                     sc=cache["sc"].at[si].set(c2),
                     sn=cache["sn"].at[si].set(n2))
    cache = dict(cache, mS=jnp.concatenate(new_mS, 0), len=cache["len"] + 1)
    return x_cur, cache


def _hybrid_decode(params, cfg, cache, x, positions, mesh):
    clen = cache["len"]
    every = cfg.attn_every
    sh = params["shared_attn"]
    hd = cfg.hd
    b = x.shape[0]

    def m_body(carry, layer):
        h = carry
        blk, conv_s, ssm_s = layer
        hh = L.rms_norm(h, blk["ln"])
        out, (c2, s2) = ssm.mamba2_forward(hh[:, None], blk, cfg,
                                           state=(conv_s, ssm_s), decode=True)
        return h + out[:, 0], (c2, s2)

    pos = 0
    ai = 0
    new_conv, new_ssm, new_k, new_v = [], [], [], []
    while pos < cfg.n_layers:
        n = min(every, cfg.n_layers - pos)
        seg_p = jax.tree.map(lambda a: a[pos:pos + n], params["mamba"])
        seg_c = cache["conv"][pos:pos + n]
        seg_s = cache["ssm"][pos:pos + n]
        x, (c_out, s_out) = jax.lax.scan(m_body, x, (seg_p, seg_c, seg_s))
        new_conv.append(c_out)
        new_ssm.append(s_out)
        pos += n
        # shared attention after each segment
        h = L.rms_norm(x, sh["ln1"])
        q = (h @ sh["wq"]).reshape(b, 1, cfg.n_heads, hd)
        k = (h @ sh["wk"]).reshape(b, 1, cfg.n_kv_heads, hd)
        v = (h @ sh["wv"]).reshape(b, 1, cfg.n_kv_heads, hd)
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
        kc = jax.lax.dynamic_update_slice_in_dim(cache["k"][ai], k, clen, 1)
        vc = jax.lax.dynamic_update_slice_in_dim(cache["v"][ai], v, clen, 1)
        new_k.append(kc[None])
        new_v.append(vc[None])
        o = _decode_attn(q[:, 0], kc, vc, clen + 1, mesh)
        x = x + o.reshape(b, cfg.n_heads * hd) @ sh["wo"]
        h = L.rms_norm(x, sh["ln2"])
        x = x + L.mlp(h, sh.get("w_gate"), sh["w_in"], sh["w_out"], "swiglu")
        ai += 1
    cache = dict(cache, conv=jnp.concatenate(new_conv, 0),
                 ssm=jnp.concatenate(new_ssm, 0),
                 k=jnp.concatenate(new_k, 0), v=jnp.concatenate(new_v, 0),
                 len=clen + 1)
    return x, cache


def _encdec_decode(params, cfg, cache, x, positions, enc_h):
    clen = cache["len"]
    b = x.shape[0]
    hd = cfg.hd

    def body(carry, layer):
        h = carry
        (blk, xblk), kc, vc = layer
        hh = L.rms_norm(h, blk["ln1"])
        q = (hh @ blk["wq"]).reshape(b, 1, cfg.n_heads, hd)
        k = (hh @ blk["wk"]).reshape(b, 1, cfg.n_kv_heads, hd)
        v = (hh @ blk["wv"]).reshape(b, 1, cfg.n_kv_heads, hd)
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
        kc = jax.lax.dynamic_update_slice_in_dim(kc, k, clen, 1)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, v, clen, 1)
        o = _decode_attn(q[:, 0], kc, vc, clen + 1, None)
        h = h + o.reshape(b, cfg.n_heads * hd) @ blk["wo"]
        # cross attention over encoder states
        hh = L.rms_norm(h, xblk["ln_x"])
        q = (hh @ xblk["xq"]).reshape(b, cfg.n_heads, hd)
        ke = (enc_h @ xblk["xk"]).reshape(b, -1, cfg.n_kv_heads, hd)
        ve = (enc_h @ xblk["xv"]).reshape(b, -1, cfg.n_kv_heads, hd)
        o = _decode_attn(q, ke, ve, jnp.asarray(ke.shape[1]), None)
        h = h + o.reshape(b, cfg.n_heads * hd) @ xblk["xo"]
        hh = L.rms_norm(h, blk["ln2"])
        h = h + _ffn(hh[:, None, :], blk, cfg)[:, 0]
        return h, (kc, vc)

    x, (ks, vs) = jax.lax.scan(body, x, ((params["blocks"], params["cross"]),
                                         cache["k"], cache["v"]))
    return x, dict(cache, k=ks, v=vs, len=clen + 1)


def encode(params, cfg: ModelConfig, enc_embeds):
    """Encoder pass for enc-dec serving (frontend stub output -> memory)."""
    enc_pos = jnp.broadcast_to(jnp.arange(enc_embeds.shape[1])[None],
                               enc_embeds.shape[:2])
    e = _scan_blocks(enc_embeds.astype(_dt(cfg)), params["enc_blocks"], cfg,
                     enc_pos, None, causal=False)
    return L.rms_norm(e, params["enc_norm"])


# ===========================================================================
# prefill: process a prompt, return (last-token logits, populated cache)
# ===========================================================================

def prefill(params, cfg: ModelConfig, tokens, max_len: int, enc_embeds=None,
            pos3=None, mesh=None):
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    if cfg.attn == "mrope" and pos3 is None:
        pos3 = jnp.broadcast_to(positions[None], (3, b, s))
    x = params["embed"][tokens].astype(_dt(cfg))
    pad_s = max_len - s

    def pad_cache_seq(a):  # [L?, B, S, ...] -> padded to max_len on axis -3/1
        widths = [(0, 0)] * a.ndim
        widths[-3 if a.ndim >= 4 else 1] = (0, pad_s)
        return jnp.pad(a, widths)

    if cfg.kind in ("dense", "moe") and cfg.attn != "mla":
        def body(h, blk):
            hh = L.rms_norm(h, blk["ln1"])
            a, kv = _attn_prefill(hh, blk, cfg, positions, pos3, with_kv=True)
            h = h + a
            hh = L.rms_norm(h, blk["ln2"])
            return h + _ffn(hh, blk, cfg, mesh), kv

        fn = jax.checkpoint(body, static_argnums=()) if cfg.remat else body
        x, (ks, vs) = jax.lax.scan(fn, x, params["blocks"])
        cache = {"k": jnp.pad(ks, ((0, 0), (0, 0), (0, pad_s), (0, 0), (0, 0))),
                 "v": jnp.pad(vs, ((0, 0), (0, 0), (0, pad_s), (0, 0), (0, 0))),
                 "len": jnp.asarray(s, jnp.int32)}
    elif cfg.attn == "mla":
        m = cfg.mla

        def body(h, blk):
            hh = L.rms_norm(h, blk["ln1"])
            kv = hh @ blk["wkv_a"]
            ckv = L.rms_norm(kv[..., :m.kv_lora], blk["kv_ln"])
            kpe_r = L.apply_rope(kv[..., m.kv_lora:][:, :, None, :], positions,
                                 cfg.rope_theta)[:, :, 0]
            a = _mla_prefill(hh, blk, cfg, positions)
            h = h + a
            hh = L.rms_norm(h, blk["ln2"])
            return h + _ffn(hh, blk, cfg, mesh), (ckv, kpe_r)

        fn = jax.checkpoint(body) if cfg.remat else body
        x, (ckvs, kpes) = jax.lax.scan(fn, x, params["blocks"])
        cache = {"ckv": jnp.pad(ckvs, ((0, 0), (0, 0), (0, pad_s), (0, 0))),
                 "kpe": jnp.pad(kpes, ((0, 0), (0, 0), (0, pad_s), (0, 0))),
                 "len": jnp.asarray(s, jnp.int32)}
    elif cfg.kind == "xlstm":
        x, cache = _xlstm_prefill(x, params, cfg)
    elif cfg.kind == "hybrid":
        x, cache = _hybrid_prefill(x, params, cfg, positions, max_len, pad_s)
    elif cfg.kind == "encdec":
        enc_h = encode(params, cfg, enc_embeds)

        def body(h, layer):
            blk, xblk = layer
            hh = L.rms_norm(h, blk["ln1"])
            a, kv = _attn_prefill(hh, blk, cfg, positions, None, with_kv=True)
            h = h + a
            hh = L.rms_norm(h, xblk["ln_x"])
            bq = (hh @ xblk["xq"]).reshape(b, s, cfg.n_heads, cfg.hd)
            ke = (enc_h @ xblk["xk"]).reshape(b, -1, cfg.n_kv_heads, cfg.hd)
            ve = (enc_h @ xblk["xv"]).reshape(b, -1, cfg.n_kv_heads, cfg.hd)
            o = L.jnp_flash_attention(bq, ke, ve, causal=False)
            h = h + o.reshape(b, s, cfg.n_heads * cfg.hd) @ xblk["xo"]
            hh = L.rms_norm(h, blk["ln2"])
            return h + _ffn(hh, blk, cfg), kv

        fn = jax.checkpoint(body) if cfg.remat else body
        x, (ks, vs) = jax.lax.scan(fn, x, (params["blocks"], params["cross"]))
        cache = {"k": jnp.pad(ks, ((0, 0), (0, 0), (0, pad_s), (0, 0), (0, 0))),
                 "v": jnp.pad(vs, ((0, 0), (0, 0), (0, pad_s), (0, 0), (0, 0))),
                 "enc_h": enc_h, "len": jnp.asarray(s, jnp.int32)}
    else:
        raise ValueError(cfg.kind)
    x = L.rms_norm(x[:, -1], params["final_norm"])
    logits = x.astype(jnp.float32) @ params["embed"].T.astype(jnp.float32)
    return logits, cache


def _xlstm_prefill(x, params, cfg):
    seg = cfg.slstm_every
    n_seg = cfg.n_layers // seg
    per = seg - 1
    b = x.shape[0]
    d_in = cfg.ssm_expand * cfg.d_model
    dh = d_in // cfg.n_heads

    def m_body(h, layer):
        hh = L.rms_norm(h, layer["ln"])
        out, (S,) = ssm.mlstm_forward(hh, layer, cfg)
        return h + out, S

    mS_all, sh_all, sc_all, sn_all = [], [], [], []
    for si in range(n_seg):
        seg_p = jax.tree.map(lambda a: a[si * per:(si + 1) * per],
                             params["mlstm"])
        x, S_seg = jax.lax.scan(m_body, x, seg_p)
        mS_all.append(S_seg)
        sl = jax.tree.map(lambda a: a[si], params["slstm"])
        hh = L.rms_norm(x, sl["ln"])
        out, (h2, c2, n2) = ssm.slstm_forward(hh, sl, cfg)
        x = x + out
        sh_all.append(h2[None])
        sc_all.append(c2[None])
        sn_all.append(n2[None])
    cache = {"mS": jnp.concatenate(mS_all, 0),
             "sh": jnp.concatenate(sh_all, 0),
             "sc": jnp.concatenate(sc_all, 0),
             "sn": jnp.concatenate(sn_all, 0),
             "len": jnp.asarray(x.shape[1], jnp.int32)}
    return x, cache


def _hybrid_prefill(x, params, cfg, positions, max_len, pad_s):
    b, s, _ = x.shape
    sh = params["shared_attn"]
    every = cfg.attn_every
    hd = cfg.hd

    def m_body(h, layer):
        hh = L.rms_norm(h, layer["ln"])
        out, (conv_s, ssm_s) = ssm.mamba2_forward(hh, layer, cfg)
        return h + out, (conv_s, ssm_s)

    pos = 0
    convs, ssms, ks, vs = [], [], [], []
    while pos < cfg.n_layers:
        n = min(every, cfg.n_layers - pos)
        seg_p = jax.tree.map(lambda a: a[pos:pos + n], params["mamba"])
        x, (c_seg, s_seg) = jax.lax.scan(m_body, x, seg_p)
        convs.append(c_seg)
        ssms.append(s_seg)
        pos += n
        h = L.rms_norm(x, sh["ln1"])
        a, (k, v) = _attn_prefill(h, sh, cfg, positions, None, causal=True,
                                  with_kv=True)
        ks.append(k[None])
        vs.append(v[None])
        x = x + a
        h = L.rms_norm(x, sh["ln2"])
        x = x + L.mlp(h, sh.get("w_gate"), sh["w_in"], sh["w_out"], "swiglu")
    cache = {"conv": jnp.concatenate(convs, 0),
             "ssm": jnp.concatenate(ssms, 0),
             "k": jnp.pad(jnp.concatenate(ks, 0),
                          ((0, 0), (0, 0), (0, pad_s), (0, 0), (0, 0))),
             "v": jnp.pad(jnp.concatenate(vs, 0),
                          ((0, 0), (0, 0), (0, pad_s), (0, 0), (0, 0))),
             "len": jnp.asarray(s, jnp.int32)}
    return x, cache
