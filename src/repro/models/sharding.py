"""PartitionSpec trees for the production mesh.

Policy (DESIGN.md Sec. 5): batch over (pod, data); vocab + attention-head /
ffn / expert dims over `model`; KV projections replicated over `model`
(avoids kv_heads < mesh divisibility issues — the cache itself is S-sharded
at decode); FSDP models additionally shard the d_model dim of large weights
over `data`.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig


def _leaf_spec(name: str, shape, cfg: ModelConfig, stacked: bool) -> P:
    fs = "data" if cfg.fsdp else None
    tp = "model"

    def wrap(*dims):
        return P(*(((None,) if stacked else ()) + dims))

    # norms / small vectors
    if len(shape) - (1 if stacked else 0) <= 1:
        return wrap(None)
    if name == "embed":
        return P(tp, fs)
    if name in ("wq", "xq", "w_gate", "w_in", "sh_gate", "sh_in", "w_q",
                "w_k", "w_v", "w_o", "w_z", "w_gates", "r_gates", "wq_b",
                "wkv_b"):
        return wrap(fs, tp)
    if name in ("wk", "wv", "xk", "xv", "wq_a", "wkv_a", "w_bc", "w_dt"):
        return wrap(fs, None)
    if name in ("wo", "xo", "w_out", "sh_out"):
        return wrap(tp, fs)
    if name == "router":
        return wrap(fs, None)
    if name in ("e_gate", "e_in"):
        return wrap(tp, fs, None)
    if name == "e_out":
        return wrap(tp, None, fs)
    if name == "conv_w":
        return wrap(None, tp)
    return wrap(*([None] * (len(shape) - (1 if stacked else 0))))


_STACKED_GROUPS = ("blocks", "enc_blocks", "cross", "mlstm", "slstm", "mamba")


def _fit(spec: P, shape, mesh) -> P:
    """Drop sharding on axes the dimension size can't divide evenly."""
    axes = tuple(spec) + (None,) * (len(shape) - len(spec))
    out = []
    for dim, ax in zip(shape, axes):
        if ax is None:
            out.append(None)
            continue
        names = ax if isinstance(ax, tuple) else (ax,)
        ways = 1
        for a in names:
            ways *= mesh.shape[a]
        out.append(ax if (ways and dim % ways == 0) else None)
    return P(*out)


def param_pspecs(cfg: ModelConfig, shapes: Dict[str, Any], mesh) -> Dict[str, Any]:
    """PartitionSpec tree mirroring ``param_shapes(cfg)``."""

    def walk(tree, group):
        out = {}
        for k, v in tree.items():
            if isinstance(v, dict):
                out[k] = walk(v, k)
            else:
                stacked = group in _STACKED_GROUPS
                out[k] = _fit(_leaf_spec(k, v, cfg, stacked), v, mesh)
        return out

    return walk(shapes, "")


def batch_axes(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def batch_spec(mesh, batch: int) -> P:
    """Shard batch over (pod, data) when divisible; else replicate."""
    axes = batch_axes(mesh)
    ways = 1
    for a in axes:
        ways *= mesh.shape[a]
    if batch % max(ways, 1) == 0 and batch >= ways:
        return P(axes)
    return P(None)


def cache_pspecs(cfg: ModelConfig, cache: Dict[str, Any], mesh,
                 batch: int) -> Dict[str, Any]:
    """KV caches: batch over data, S over model (flash-decode sharding);
    SSM states: batch over data, heads over model when divisible."""
    bspec = batch_spec(mesh, batch)
    b_ax = bspec[0] if len(bspec) else None

    def spec(k, v):
        if k == "len":
            return P()
        if k in ("k", "v"):        # [L?, B, S, kv, hd]
            lead = (None,) if v.ndim == 5 else ()
            return P(*(lead + (b_ax, "model", None, None)))
        if k in ("ckv", "kpe"):    # [L, B, S, d]
            return P(None, b_ax, "model", None)
        if k == "conv":            # [L, B, W-1, d_in]
            return P(None, b_ax, None, "model")
        if k == "ssm":             # [L, B, H, state, dh]
            h = cfg.n_heads
            tp = "model" if h % mesh.shape["model"] == 0 else None
            return P(None, b_ax, tp, None, None)
        if k == "mS":              # [L, B, H, dh, dh+1]
            return P(None, b_ax, None, None, None)
        if k in ("sh", "sc", "sn"):  # [seg, B, D]
            return P(None, b_ax, None)
        if k == "enc_h":           # [B, S_src, D]
            return P(b_ax, None, None)
        return P(*([None] * v.ndim))

    return {k: _fit(spec(k, v), v.shape, mesh) for k, v in cache.items()}


def to_shape_dtype(tree, mesh, pspecs):
    """Attach NamedShardings to a ShapeDtypeStruct tree (dry-run inputs)."""
    return jax.tree.map(
        lambda x, s: jax.ShapeDtypeStruct(x.shape, x.dtype,
                                          sharding=NamedSharding(mesh, s)),
        tree, pspecs)
