"""Sequence-state models: shared chunked gated-linear-attention core
(the SSD duality — Mamba-2 and mLSTM are the same chunkwise recurrence with
different gate parameterizations), Mamba2 block, mLSTM, sLSTM.

Recurrence:  S_t = a_t * S_{t-1} + g_t * k_t v_t^T ;  y_t = q_t · S_t
Chunkwise:   intra-chunk attention with decay matrix D_ij = exp(L_i - L_j),
             inter-chunk via the carried state — one lax.scan over chunks,
             O(S·C) memory, matmul-dominated (MXU-friendly).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def chunked_gla(q, k, v, log_a, gate, chunk: int = 128,
                state0: Optional[jax.Array] = None):
    """q,k: [B,S,H,dk]; v: [B,S,H,dv]; log_a, gate: [B,S,H].

    Returns (y [B,S,H,dv], final_state [B,H,dk,dv]).
    log_a <= 0 (per-token log decay); gate >= 0 (input gate).
    """
    b, s, h, dk = q.shape
    dv = v.shape[-1]
    chunk = min(chunk, s)
    nc = -(-s // chunk)
    pad = nc * chunk - s
    if pad:
        z4 = ((0, 0), (0, pad), (0, 0), (0, 0))
        z3 = ((0, 0), (0, pad), (0, 0))
        q, k, v = jnp.pad(q, z4), jnp.pad(k, z4), jnp.pad(v, z4)
        log_a, gate = jnp.pad(log_a, z3), jnp.pad(gate, z3)

    def to_chunks(x):
        return x.reshape((b, nc, chunk) + x.shape[2:]).swapaxes(0, 1)

    qc, kc, vc = to_chunks(q), to_chunks(k), to_chunks(v)
    lac, gc = to_chunks(log_a), to_chunks(gate)

    tri = jnp.tril(jnp.ones((chunk, chunk), bool))

    def step(S, inp):
        qb, kb, vb, la, g = inp            # [B,C,H,*], [B,C,H]
        L = jnp.cumsum(la, axis=1)         # [B,C,H] inclusive
        total = L[:, -1:, :]               # [B,1,H]
        # intra-chunk: D_ij = exp(L_i - L_j) for j<=i, times gate_j
        Ld = L[:, :, None, :] - L[:, None, :, :]           # [B,C,C,H] i,j
        D = jnp.where(tri[None, :, :, None], jnp.exp(Ld), 0.0)
        sc = jnp.einsum("bihd,bjhd->bijh", qb.astype(jnp.float32),
                        kb.astype(jnp.float32))
        w = sc * D * g[:, None, :, :]
        y_intra = jnp.einsum("bijh,bjhd->bihd", w, vb.astype(jnp.float32))
        # inter-chunk from carried state
        qs = qb.astype(jnp.float32) * jnp.exp(L)[..., None]
        y_inter = jnp.einsum("bihk,bhkv->bihv", qs, S)
        # state update: S' = S*exp(total) + sum_j exp(total - L_j) g_j k_j v_j^T
        decay_j = jnp.exp(total - L) * g                   # [B,C,H]
        kS = jnp.einsum("bjhk,bjhv->bhkv",
                        kb.astype(jnp.float32) * decay_j[..., None],
                        vb.astype(jnp.float32))
        S_new = S * jnp.exp(total)[:, 0, :, None, None] + kS
        return S_new, y_intra + y_inter

    S0 = state0 if state0 is not None else jnp.zeros((b, h, dk, dv), jnp.float32)
    S_final, yc = jax.lax.scan(step, S0, (qc, kc, vc, lac, gc))
    y = yc.swapaxes(0, 1).reshape(b, nc * chunk, h, dv)[:, :s]
    return y, S_final


def gla_decode_step(S, q, k, v, log_a, gate):
    """Single-token recurrence. S: [B,H,dk,dv]; q,k: [B,H,dk]; v: [B,H,dv];
    log_a, gate: [B,H]. Returns (y [B,H,dv], S')."""
    a = jnp.exp(log_a)[..., None, None]
    S_new = S * a + jnp.einsum("bhk,bhv->bhkv",
                               (k * gate[..., None]).astype(jnp.float32),
                               v.astype(jnp.float32))
    y = jnp.einsum("bhk,bhkv->bhv", q.astype(jnp.float32), S_new)
    return y, S_new


# ---------------------------------------------------------------------------
# Mamba2 block (SSD): conv -> gates -> chunked scan -> gated output
# ---------------------------------------------------------------------------

def mamba2_forward(x, p, cfg, state: Optional[Tuple] = None, decode=False):
    """x: [B,S,D] (S=1 when decode). p: layer params dict.
    state: (conv_state [B,W-1,d_in], ssm_state [B,H,dstate,dh])."""
    b, s, d = x.shape
    d_in = cfg.ssm_expand * cfg.d_model
    h = cfg.n_heads
    dh = d_in // h
    xz = x @ p["w_in"]                                   # [B,S,d_in]
    z = x @ p["w_z"]
    bc = x @ p["w_bc"]                                   # [B,S,2*dstate]
    dt = jax.nn.softplus(x @ p["w_dt"] + p["dt_bias"])   # [B,S,H]
    B_, C_ = jnp.split(bc, 2, axis=-1)                   # [B,S,dstate]
    # depthwise causal conv over sequence
    w = cfg.conv_width
    if decode:
        conv_state = state[0]                            # [B, w-1, d_in]
        window = jnp.concatenate([conv_state, xz], axis=1)  # [B, w, d_in]
        xc = jnp.einsum("bwd,wd->bd", window, p["conv_w"])[:, None, :]
        new_conv_state = window[:, 1:]
    else:
        xc = _causal_depthwise_conv(xz, p["conv_w"])
        new_conv_state = xz[:, -(w - 1):] if s >= w - 1 else jnp.pad(
            xz, ((0, 0), (w - 1 - s, 0), (0, 0)))
    xc = jax.nn.silu(xc)
    xh = xc.reshape(b, -1, h, dh)                        # [B,S,H,dh]
    log_a = -dt * jnp.exp(p["A_log"])                    # [B,S,H]
    # B_, C_ shared across heads (n_groups=1)
    k = jnp.broadcast_to(B_[:, :, None, :], (b, xh.shape[1], h, B_.shape[-1]))
    q = jnp.broadcast_to(C_[:, :, None, :], k.shape)
    gate = dt                                            # input scale
    if decode:
        y, ssm_state = gla_decode_step(state[1], q[:, 0], k[:, 0], xh[:, 0],
                                       log_a[:, 0], gate[:, 0])
        y = y[:, None]
    else:
        y, ssm_state = chunked_gla(q, k, xh, log_a, gate)
    y = y + xh.astype(jnp.float32) * p["D_skip"][None, None, :, None]
    y = y.reshape(b, -1, d_in).astype(x.dtype)
    y = y * jax.nn.silu(z)
    out = y @ p["w_out"]
    return out, (new_conv_state, ssm_state)


def _causal_depthwise_conv(x, w):
    """x: [B,S,C]; w: [W,C] — depthwise causal conv."""
    width = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = 0.0
    for i in range(width):
        out = out + xp[:, i:i + x.shape[1]] * w[i]
    return out


# ---------------------------------------------------------------------------
# mLSTM block (xLSTM): matrix memory, chunkwise via the same GLA core
# ---------------------------------------------------------------------------

def mlstm_forward(x, p, cfg, state: Optional[Tuple] = None, decode=False):
    """x: [B,S,D]. Matrix-memory LSTM with normalizer (denominator tracked
    by augmenting v with a ones column).

    Simplification noted in DESIGN.md: sigmoid-normalized input gates stand
    in for the exponential-gate + global-stabilizer kernel detail; compute
    and memory structure (and thus the roofline) are unchanged.
    """
    b, s, d = x.shape
    d_in = cfg.ssm_expand * cfg.d_model
    h = cfg.n_heads
    dh = d_in // h
    q = (x @ p["w_q"]).reshape(b, s, h, dh)
    k = (x @ p["w_k"]).reshape(b, s, h, dh) * (dh ** -0.5)
    v = (x @ p["w_v"]).reshape(b, s, h, dh)
    gates = x @ p["w_gates"]                              # [B,S,2H]
    i_g = jax.nn.sigmoid(gates[..., :h])
    f_g = jax.nn.sigmoid(gates[..., h:]) * 0.999 + 0.0005
    log_a = jnp.log(f_g)
    v_aug = jnp.concatenate([v, jnp.ones_like(v[..., :1])], axis=-1)
    if decode:
        y_aug, S = gla_decode_step(state[0], q[:, 0], k[:, 0], v_aug[:, 0],
                                   log_a[:, 0], i_g[:, 0])
        y_aug = y_aug[:, None]
    else:
        s0 = state[0] if state is not None else None
        y_aug, S = chunked_gla(q, k, v_aug, log_a, i_g, state0=s0)
    y = y_aug[..., :dh] / jnp.maximum(jnp.abs(y_aug[..., dh:]), 1e-2)
    y = y.reshape(b, -1, d_in).astype(x.dtype)
    o = jax.nn.sigmoid(x @ p["w_o"])
    out = (y * o) @ p["w_out"]
    return out, (S,)


# ---------------------------------------------------------------------------
# sLSTM block: scalar memory, sequential token scan (not parallelizable —
# the xLSTM paper's own caveat; on TPU this is a lax.scan)
# ---------------------------------------------------------------------------

def slstm_forward(x, p, cfg, state: Optional[Tuple] = None, decode=False):
    """x: [B,S,D]. Gates from input + recurrent hidden projection."""
    b, s, d = x.shape
    hdim = d  # hidden size = d_model

    def cell(carry, xt):
        hprev, cprev, nprev = carry
        g = xt @ p["w_gates"] + hprev @ p["r_gates"]      # [B, 4D]
        i_t = jnp.exp(jnp.clip(g[..., :d], -10, 5))
        f_t = jax.nn.sigmoid(g[..., d:2 * d])
        z_t = jnp.tanh(g[..., 2 * d:3 * d])
        o_t = jax.nn.sigmoid(g[..., 3 * d:])
        c = f_t * cprev + i_t * z_t
        n = f_t * nprev + i_t
        hnew = o_t * c / jnp.maximum(n, 1.0)
        return (hnew, c, n), hnew

    if state is None:
        state = (jnp.zeros((b, hdim), jnp.float32),
                 jnp.zeros((b, hdim), jnp.float32),
                 jnp.zeros((b, hdim), jnp.float32))
    if decode:
        carry, h_seq = cell(state, x[:, 0].astype(jnp.float32))
        h_seq = h_seq[:, None]
    else:
        carry, h_seq = jax.lax.scan(cell, state,
                                    x.swapaxes(0, 1).astype(jnp.float32))
        h_seq = h_seq.swapaxes(0, 1)
    out = h_seq.astype(x.dtype) @ p["w_out"]
    return out, carry
