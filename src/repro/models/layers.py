"""Transformer layers: norms, rotary (incl. M-RoPE), attention, MLP, MoE.

Attention paths:
  * prefill: chunked flash attention in pure jnp (lax.scan over KV blocks,
    online softmax) — compile-friendly, O(S·chunk) memory, identical FLOPs
    to the Pallas flash_attention kernel which replaces it on real TPUs.
  * decode: one-token attention over an S-sharded KV cache via shard_map —
    per-shard partial softmax (the flash_decode kernel's math) merged with
    log-sum-exp psum over the `model` axis. This is R3-1's
    partition-compute-aggregate applied to the cache (DESIGN.md Sec. 5).

MoE: GShard-style capacity dispatch built on sort (no [T,E,C] one-hot
tensors), experts sharded over `model` (expert parallelism).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.config import ModelConfig


# ---------------------------------------------------------------------------
# norms + rotary
# ---------------------------------------------------------------------------

def rms_norm(x, g, eps=1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)).astype(x.dtype) * g


def rope_freqs(hd: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x, positions, theta: float = 1e4):
    """x: [B, S, H, hd]; positions: [B, S] int."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)
    ang = positions[..., None].astype(jnp.float32) * freqs  # [B,S,hd/2]
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., ::2], x[..., 1::2]
    out = jnp.stack([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.reshape(x.shape).astype(x.dtype)


def apply_mrope(x, positions3, sections=(16, 24, 24), theta: float = 1e6):
    """Qwen2-VL multimodal rotary: the hd/2 frequency slots are partitioned
    into (t, h, w) sections, each rotated by its own position id.
    positions3: [3, B, S]."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # [hd/2]
    sec = jnp.concatenate([jnp.full((s,), i, jnp.int32)
                           for i, s in enumerate(sections)])[: hd // 2]
    pos = positions3[sec]                               # [hd/2, B, S] gather
    pos = jnp.moveaxis(pos, 0, -1)                      # [B, S, hd/2]
    ang = pos.astype(jnp.float32) * freqs
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., ::2], x[..., 1::2]
    out = jnp.stack([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.reshape(x.shape).astype(x.dtype)


# ---------------------------------------------------------------------------
# attention — prefill (chunked flash, pure jnp)
# ---------------------------------------------------------------------------

def jnp_flash_attention(q, k, v, *, causal: bool = True, chunk: int = 1024,
                        scale: Optional[float] = None):
    """q: [B,S,H,hd]; k,v: [B,S,Hkv,hd]. Online-softmax scan over KV chunks."""
    b, s, h, hd = q.shape
    skv = k.shape[1]
    hkv = k.shape[2]
    dv = v.shape[3]
    group = h // hkv
    scale = scale if scale is not None else hd ** -0.5
    chunk = min(chunk, skv)
    n_chunks = -(-skv // chunk)
    pad = n_chunks * chunk - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(b, n_chunks, chunk, hkv, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n_chunks, chunk, hkv, dv).transpose(1, 0, 2, 3, 4)
    qg = q.reshape(b, s, hkv, group, hd)
    rows = jnp.arange(s)

    def step(carry, inp):
        acc, m, l = carry
        kb, vb, ci = inp
        sc = jnp.einsum("bsngd,bcnd->bnsgc", qg.astype(jnp.float32),
                        kb.astype(jnp.float32)) * scale  # [B,Hkv,S,G,C]
        cols = ci * chunk + jnp.arange(chunk)
        valid = cols[None, :] < skv
        if causal:
            valid = valid & (rows[:, None] >= cols[None, :])
        sc = jnp.where(valid[None, None, :, None, :], sc, -1e30)
        m_new = jnp.maximum(m, sc.max(-1))
        p = jnp.exp(sc - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = alpha * l + p.sum(-1)
        # (casting p to bf16 for the PV matmul was tried and REFUTED: the
        # extra convert at a fusion boundary costs more traffic than the
        # halved p saves — EXPERIMENTS §Perf iteration B2)
        pv = jnp.einsum("bnsgc,bcnd->bnsgd", p, vb.astype(jnp.float32))
        acc_new = acc * alpha[..., None] + pv
        return (acc_new, m_new, l_new), None

    acc0 = jnp.zeros((b, hkv, s, group, dv), jnp.float32)
    m0 = jnp.full((b, hkv, s, group), -1e30, jnp.float32)
    l0 = jnp.zeros((b, hkv, s, group), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(step, (acc0, m0, l0),
                                  (kc, vc, jnp.arange(n_chunks)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = out.transpose(0, 2, 1, 3, 4).reshape(b, s, h, dv)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# attention — decode over an S-sharded cache (shard_map + lse psum)
# ---------------------------------------------------------------------------

def _decode_partials_jnp(q, k, v, valid_len, scale):
    """q: [B,H,hd]; k,v: [B,Sloc,Hkv,hd]; valid_len: scalar — how many local
    slots are filled. Returns unnormalized (acc, m, l)."""
    b, h, hd = q.shape
    hkv = k.shape[2]
    group = h // hkv
    qg = q.reshape(b, hkv, group, hd)
    s = jnp.einsum("bngd,bcnd->bngc", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    cols = jnp.arange(k.shape[1])
    s = jnp.where(cols[None, None, None, :] < valid_len, s, -1e30)
    m = s.max(-1)
    p = jnp.exp(s - m[..., None])
    l = p.sum(-1)
    acc = jnp.einsum("bngc,bcnd->bngd", p, v.astype(jnp.float32))
    return acc, m, l


def sharded_decode_attention(q, k_cache, v_cache, cache_len, mesh,
                             seq_axis: str = "model"):
    """One-token attention with the cache's S axis sharded over `seq_axis`.

    q: [B, H, hd] (replicated over seq_axis); k/v_cache: [B, S, Hkv, hd]
    (S sharded). Each shard computes flash-decode partials on its local
    slice; partials merge with a log-sum-exp psum — O(B·H·hd) collective
    instead of all-gathering the cache.
    """
    hd = q.shape[-1]
    scale = hd ** -0.5
    s_total = k_cache.shape[1]
    n_shards = mesh.shape[seq_axis]
    s_loc = s_total // n_shards

    def local(qb, kb, vb, clen):
        idx = jax.lax.axis_index(seq_axis)
        start = idx * s_loc
        valid = jnp.clip(clen - start, 0, s_loc)
        acc, m, l = _decode_partials_jnp(qb, kb, vb, valid, scale)
        # lse merge across shards
        m_all = jax.lax.pmax(m, seq_axis)
        w = jnp.exp(m - m_all)
        num = jax.lax.psum(acc * w[..., None], seq_axis)
        den = jax.lax.psum(l * w, seq_axis)
        return num / jnp.maximum(den, 1e-30)[..., None]

    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names) or None
    if batch_axes is not None:
        ways = 1
        for a in batch_axes:
            ways *= mesh.shape[a]
        if q.shape[0] % ways != 0:
            batch_axes = None  # e.g. batch=1 long-context decode
    spec_q = P(batch_axes, None, None)
    spec_kv = P(batch_axes, seq_axis, None, None)
    out = jax.shard_map(
        local, mesh=mesh,
        in_specs=(spec_q, spec_kv, spec_kv, P()),
        out_specs=spec_q,
    )(q, k_cache, v_cache, cache_len)
    b, h = q.shape[0], q.shape[1]
    return out.reshape(b, h, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# MLP + MoE
# ---------------------------------------------------------------------------

def mlp(x, w_gate, w_in, w_out, act: str):
    if act == "swiglu":
        g = jax.nn.silu(x @ w_gate)
        h = g * (x @ w_in)
    elif act == "squared_relu":
        h = jnp.square(jax.nn.relu(x @ w_in))
    elif act == "gelu":
        h = jax.nn.gelu(x @ w_in)
    else:
        raise ValueError(act)
    return h @ w_out


def _moe_dispatch_compute(x, router_w, e_gate, e_in, e_out, cfg: ModelConfig,
                          e_lo: int, e_count: int, e_total: int):
    """Core MoE: route, sort-dispatch to experts [e_lo, e_lo+e_count),
    compute, weighted-combine. Pure (no collectives); the expert-parallel
    wrapper runs it per model shard."""
    mo = cfg.moe
    t, d = x.shape
    k = mo.top_k
    if t <= 256:
        cap = t  # dropless for decode/small batches (exactness matters there)
    else:
        cap = max(int(mo.capacity_factor * t * k / e_total) + 1, 4)
    logits = (x @ router_w).astype(jnp.float32)          # [T, E_total]
    gates = jax.nn.softmax(logits, axis=-1)
    topv, tope = jax.lax.top_k(gates, k)                 # [T, k] global ids
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)
    flat_e = tope.reshape(-1)
    flat_w = topv.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(t), k)
    # assignments outside this shard's expert range go to the drop bucket
    local = (flat_e >= e_lo) & (flat_e < e_lo + e_count)
    flat_e = jnp.where(local, flat_e - e_lo, e_count)
    order = jnp.argsort(flat_e)                          # group by expert
    se, sw, stok = flat_e[order], flat_w[order], flat_tok[order]
    ones = jnp.ones_like(se)
    pos_in_e = jax.lax.associative_scan(jnp.add, ones) - 1
    seg_start = jnp.searchsorted(se, jnp.arange(e_count))
    pos_in_e = pos_in_e - seg_start[jnp.minimum(se, e_count - 1)]
    keep = (pos_in_e < cap) & (se < e_count)
    slot = jnp.where(keep, se * cap + pos_in_e, e_count * cap)
    buf = jnp.zeros((e_count * cap + 1, d), x.dtype).at[slot].set(
        x[stok], mode="drop")
    buf = buf[:-1].reshape(e_count, cap, d)
    if cfg.act == "swiglu":
        g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, e_gate))
        h = g * jnp.einsum("ecd,edf->ecf", buf, e_in)
    else:
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", buf, e_in))
    y = jnp.einsum("ecf,efd->ecd", h, e_out).reshape(e_count * cap, d)
    y = jnp.concatenate([y, jnp.zeros((1, d), y.dtype)], axis=0)
    # combine in the activation dtype: keeps the [T, D] buffers and the
    # cross-shard psum in bf16 (§Perf iteration B3)
    out = jnp.zeros((t, d), x.dtype)
    contrib = y[jnp.where(keep, slot, e_count * cap)] * sw[:, None].astype(y.dtype)
    out = out.at[stok].add(contrib.astype(x.dtype), mode="drop")
    return out


def moe_block(x, router_w, e_gate, e_in, e_out, cfg: ModelConfig, mesh=None):
    """x: [T, D]. Sort-based capacity dispatch (GShard-style).

    mesh=None: single-device path (smoke tests).
    mesh given: explicit expert parallelism via shard_map — tokens stay
    batch-sharded (replicated over `model`), each model shard routes to its
    local experts, and the combine is one psum of the [T_loc, D] output.
    Without this, GSPMD lowers the combine scatter to replicated
    [T·k, D] all-reduces — 6.1 TB/step on granite-moe (EXPERIMENTS §Perf
    iteration B1)."""
    mo = cfg.moe
    e = mo.n_experts
    if mesh is None or "model" not in mesh.axis_names \
            or e % mesh.shape["model"] != 0:
        return _moe_dispatch_compute(x, router_w, e_gate, e_in, e_out, cfg,
                                     0, e, e)
    n_shards = mesh.shape["model"]
    e_loc = e // n_shards
    t = x.shape[0]
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names) or None
    if batch_axes is not None:
        ways = 1
        for a in batch_axes:
            ways *= mesh.shape[a]
        if t % ways != 0:
            batch_axes = None

    def body(xl, rw, eg, ei, eo):
        idx = jax.lax.axis_index("model")
        out = _moe_dispatch_compute(xl, rw, eg, ei, eo, cfg,
                                    e_lo=idx * e_loc, e_count=e_loc,
                                    e_total=e)
        return jax.lax.psum(out, "model")

    espec = P("model", None, None)
    return jax.shard_map(
        body, mesh=mesh,
        in_specs=(P(batch_axes, None), P(None, None), espec, espec,
                  P("model", None, None)),
        out_specs=P(batch_axes, None),
    )(x, router_w, e_gate, e_in, e_out)
