"""Quickstart: build a catalog, register an ML model, write an inference
query in the three-level IR, optimize it with MCTS, execute, verify.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import ir
from repro.core.executor import execute
from repro.core.planner import analytic_cost_fn, optimize_vanilla_mcts, timed
from repro.mlfuncs import builders
from repro.mlfuncs.registry import Registry
from repro.relational.table import Table


def main():
    rng = np.random.default_rng(0)

    # 1. base tables (paper Fig. 3: preprocessed user/movie features)
    users = Table.from_columns({
        "user_id": jnp.arange(200, dtype=jnp.int32),
        "age": jnp.asarray(rng.integers(18, 80, 200), jnp.float32),
        "user_f": jnp.asarray(rng.standard_normal((200, 32)), jnp.float32)})
    movies = Table.from_columns({
        "movie_id": jnp.arange(80, dtype=jnp.int32),
        "genre": jnp.asarray(rng.integers(0, 18, 80), jnp.int32),
        "movie_f": jnp.asarray(rng.standard_normal((80, 16)), jnp.float32)})
    catalog = ir.Catalog()
    catalog.add("users", users)
    catalog.add("movies", movies)

    # 2. load + register the two-tower model (Fig. 3 steps 1-2)
    registry = Registry()
    registry.register(builders.two_tower("two_tower", [32, 64, 16],
                                         [16, 64, 16], seed=1))
    trending = builders.ffnn("trending", [16, 32, 1], seed=2)
    trending.selectivity_hint = 0.5
    registry.register(trending)

    # 3. the inference query (Fig. 3 step 3): filter movies, cross join
    #    users, score each pair with the two-tower model
    query = ir.Project(
        ir.Filter(
            ir.Filter(
                ir.CrossJoin(ir.Scan("users"), ir.Scan("movies")),
                pred=ir.IsIn(ir.Col("genre"), (1, 4, 7))),
            pred=ir.Cmp(">", ir.Call("trending", (ir.Col("movie_f"),)),
                        ir.Const(0.5))),
        outputs=(("score", ir.Call("two_tower",
                                   (ir.Col("user_f"), ir.Col("movie_f")))),),
        keep=("user_id", "movie_id"))
    plan = ir.Plan(query, registry)

    # 4. optimize (reusable-MCTS action space: R1/R2/R3/R4 rules)
    cost_fn = analytic_cost_fn(catalog)
    optimized, stats = timed(optimize_vanilla_mcts, plan, catalog,
                             cost_fn=cost_fn, iterations=40)
    print(f"estimated cost: {cost_fn(plan):.3e}s -> {cost_fn(optimized):.3e}s"
          f"  ({stats['speedup']:.1f}x, optimized in {stats['opt_seconds']:.2f}s)")

    # 5. execute both (execute lowers to the physical plan layer and runs
    #    the fused pipelines), verify equivalence
    a = execute(plan, catalog).canonical()
    b = execute(optimized, catalog).canonical()
    for k in a:
        np.testing.assert_allclose(a[k], b[k], rtol=5e-4, atol=5e-4)
    print(f"results identical on {len(a['score'])} scored pairs — "
          "co-optimization is lossless.")

    # 6. serve repeated traffic through the compiled-plan cache: a second
    #    structurally identical query skips lowering AND jax tracing
    from repro.core.plan_cache import PlanCache
    cache = PlanCache()
    tables = dict(catalog.tables)
    cache.get_or_compile(optimized, catalog)(tables)   # miss: lower + trace
    cache.get_or_compile(optimized, catalog)(tables)   # hit: dispatch only
    s = cache.stats
    print(f"plan cache: hits={s.hits} misses={s.misses} "
          f"traces={cache.traces} (1 trace for 2 executions)")


if __name__ == "__main__":
    main()
