"""End-to-end recommendation workload (paper Sec. V-C1): the MovieLens
complex queries optimized by the *reusable* MCTS with trained Query2Vec
embeddings — including the state-collision speedup on repeated templates.

    PYTHONPATH=src python examples/recommendation_pipeline.py
"""
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from repro.core import optimizer as om
from repro.core.executor import execute
from repro.core.mcts import ReusableMCTS
from repro.core.planner import analytic_cost_fn
from repro.data import templates
from repro.mlfuncs import builders
from benchmarks.common import time_plan


def main():
    print("training Model2Vec/Query2Vec (contrastive, WL-mined pairs) ...")
    emb = om.init_embedder(0)
    graphs = [g for g in (builders.sample_model(s).graph for s in range(24))
              if g is not None]
    om.train_model2vec(emb, graphs, steps=40, batch=8, lr=1e-4)
    plans, cats, costs = [], [], []
    for i in range(24):
        p, c = templates.sample_query(1 + (i % 3), seed=500 + i, scale=0.5)
        plans.append(p)
        cats.append(c)
        costs.append(analytic_cost_fn(c)(p))
    om.train_query2vec(emb, plans, cats, steps=40, batch=8)
    om.train_latency(emb, plans, cats, costs, steps=80, batch=8)

    opt = ReusableMCTS(catalog_fn=None, embed_fn=emb.embed,
                       cost_fn_factory=lambda c: analytic_cost_fn(c),
                       iterations=25, warm_iterations=8, seed=0)

    print("\nquery                 opt_s   collision  est_speedup  wall_speedup")
    for i in range(6):
        plan, cat = templates.sample_query(1 + (i % 3), seed=900 + i, scale=0.5)
        t0 = time.perf_counter()
        best, stats = opt.optimize(plan, cat)
        opt_s = time.perf_counter() - t0
        base_t, _ = time_plan(plan, cat, repeats=1)
        opt_t, _ = time_plan(best, cat, repeats=1)
        a = execute(plan, cat).canonical()
        b = execute(best, cat).canonical()
        for k in a:
            np.testing.assert_allclose(a[k], b[k], rtol=5e-4, atol=5e-4)
        print(f"rec_template_{1 + (i % 3)} run{i:02d}   {opt_s:6.2f}   "
              f"{str(stats['collision']):>5}     {stats['speedup']:6.2f}x"
              f"      {base_t / max(opt_t, 1e-9):6.2f}x")
    print(f"\ncollision rate: {opt.collision_rate:.2f}  "
          f"node store: {len(opt.nodes)} states, {opt.storage_bytes()}B")


if __name__ == "__main__":
    main()
