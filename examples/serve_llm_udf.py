"""Paper Appendix K: LLM inference queries — a zoo LM served behind a
black-box ``llm_score`` ML function inside a SQL query. CACTUSDB factorizes
the call and pushes it below the cross join (R4-1 + R1-3), slashing the
number of LLM invocations exactly as the paper's token-cost reduction.

    PYTHONPATH=src python examples/serve_llm_udf.py
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core import ir
from repro.core.executor import execute
from repro.core.planner import analytic_cost_fn, optimize_vanilla_mcts
from repro.mlfuncs import builders
from repro.mlfuncs.functions import MLFunction
from repro.mlfuncs.registry import Registry
from repro.models import lm
from repro.relational.table import Table


def main():
    # a zoo model standing in for the paper's gpt-3.5 endpoint
    cfg = dataclasses.replace(get_smoke_config("granite-3-2b"), vocab=256)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    calls = {"n": 0}

    def llm_summarize(feats):
        """Black-box UDF: encode a feature row into an LM 'summary' score."""
        calls["n"] += feats.shape[0]
        toks = (jnp.abs(feats[:, :16]) * 37).astype(jnp.int32) % cfg.vocab
        h = lm.forward(params, cfg, toks)
        return h[:, -1, :8]  # summary embedding

    rng = np.random.default_rng(0)
    users = Table.from_columns({
        "user_id": jnp.arange(24, dtype=jnp.int32),
        "user_desc": jnp.asarray(rng.standard_normal((24, 16)), jnp.float32)})
    movies = Table.from_columns({
        "movie_id": jnp.arange(12, dtype=jnp.int32),
        "lang_en": jnp.asarray(rng.integers(0, 2, 12), jnp.int32),
        "movie_desc": jnp.asarray(rng.standard_normal((12, 16)), jnp.float32)})
    catalog = ir.Catalog()
    catalog.add("users", users)
    catalog.add("movies", movies)

    registry = Registry()
    registry.register(MLFunction("llm_summarize", graph=None,
                                 opaque_fn=llm_summarize, n_inputs=1))
    registry.register(builders.two_tower("recommend", [8, 16, 8], [8, 16, 8],
                                         seed=1))

    # Appendix-K Q1: LLM-summarize both sides of a cross join, then score
    q = ir.Project(
        ir.Filter(ir.CrossJoin(ir.Scan("users"), ir.Scan("movies")),
                  pred=ir.Cmp("==", ir.Col("lang_en"), ir.Const(1))),
        outputs=(("score", ir.Call("recommend", (
            ir.Call("llm_summarize", (ir.Col("user_desc"),)),
            ir.Call("llm_summarize", (ir.Col("movie_desc"),))))),),
        keep=("user_id", "movie_id"))
    plan = ir.Plan(q, registry)

    calls["n"] = 0
    base = execute(plan, catalog).canonical()
    naive_calls = calls["n"]

    opt, stats = optimize_vanilla_mcts(plan, catalog,
                                       cost_fn=analytic_cost_fn(catalog),
                                       iterations=40, seed=0)
    calls["n"] = 0
    out = execute(opt, catalog).canonical()
    opt_calls = calls["n"]
    for k in base:
        np.testing.assert_allclose(base[k], out[k], rtol=5e-4, atol=5e-4)
    print(f"LLM rows summarized: naive={naive_calls}  optimized={opt_calls}  "
          f"({naive_calls / max(opt_calls, 1):.1f}x fewer inferences, "
          "same results)")
    print("(paper Appendix K: pushing the LLM call below the cross join "
          "avoids re-summarizing the same row per pair — 72.4% token cut)")


if __name__ == "__main__":
    main()
