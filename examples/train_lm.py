"""End-to-end LM training driver: train a reduced granite-3 family model for
a few hundred steps with checkpoint/restart, demonstrating the full training
substrate (data pipeline -> sharded AdamW -> checkpoints -> resume).

    PYTHONPATH=src python examples/train_lm.py [--steps 200]

On a TPU fleet the identical entry point trains the full assigned configs via
``python -m repro.launch.train --arch granite-3-2b``.
"""
import argparse
import dataclasses
import shutil

from repro.configs import get_smoke_config
from repro.train.loop import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = dataclasses.replace(get_smoke_config("granite-3-2b"),
                              n_layers=4, d_model=128, d_ff=512, vocab=512)
    shutil.rmtree(args.ckpt_dir, ignore_errors=True)

    def hook(step, m):
        if step % 20 == 0:
            print(f"step {step:4d}  loss {m['loss']:.4f}  "
                  f"{m['dt'] * 1e3:6.1f} ms/step", flush=True)

    half = args.steps // 2
    print(f"phase 1: {half} steps with checkpointing ...")
    train(cfg, steps=half, batch=args.batch, seq=args.seq, lr=1e-3,
          ckpt_dir=args.ckpt_dir, ckpt_every=25, hook=hook)
    print("simulated restart — resuming from the latest checkpoint ...")
    res = train(cfg, steps=args.steps, batch=args.batch, seq=args.seq,
                lr=1e-3, ckpt_dir=args.ckpt_dir, ckpt_every=25, hook=hook)
    print(f"resumed from step {res.resumed_from}; "
          f"final loss {res.losses[-1]:.4f} "
          f"(from {res.losses[0]:.4f} post-resume)")


if __name__ == "__main__":
    main()
